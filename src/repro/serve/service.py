"""The long-lived multi-tenant solve service over the NAP operator stack.

``SolverService`` fronts :func:`repro.api.operator` with the production
concerns a persistent deployment needs, as ONE deterministic synchronous
pump — every externally visible decision happens at a ``step()``
boundary against an injectable clock, so fault scenarios replay exactly:

admit      ``submit()`` runs bounded admission: a full queue, an
           unmeetable deadline, an unknown matrix, or a degraded fleet
           reject immediately with a reason (never block, never
           deadlock).
batch      each step, the ready requests sort earliest-deadline-first
           and the head request's (matrix, kind) group executes as ONE
           multi-RHS apply — concurrent RHS vectors ride the executors'
           nv-tiled path instead of looping 1-RHS calls.
solve      ``kind="spmv"`` applies A once; ``kind="solve"`` runs batched
           CG (per-column convergence, shared SpMVs), checkpointing the
           iterate block every ``checkpoint_every`` iterations through
           :class:`repro.checkpoint.store.CheckpointManager`.
recover    dead nodes (heartbeat timeout) and stragglers (z-score) evict
           through one elastic path: survivor topology
           (``ElasticPolicy.survivor_topology``) → row repartition per
           matrix (``survivor_partition`` — survivors keep their rows)
           → plan-cache rebuild + eager recompile on the new layout →
           checkpoint restore of in-flight solver state → in-flight
           requests requeued for transparent re-execution.

Failures between detection windows surface as :class:`FabricError`
(a collective touching a dead rank); affected requests retry with
exponential backoff until ``max_attempts``, then fail with the error
recorded.  Matrix VALUES update through the structure-keyed
:class:`repro.serve.plancache.PlanCache` — a value-only change hot-swaps
into the cached compiled program with zero retraces.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.core.integrity import IntegrityError, MessageFault
from repro.core.partition import RowPartition, contiguous_partition, \
    survivor_partition
from repro.core.topology import Topology
from repro.runtime.fault import ElasticPolicy, HeartbeatMonitor, \
    StragglerDetector
from repro.serve.faultplan import FabricError, FaultPlan, ManualClock
from repro.serve.plancache import PlanCache

REJECT_QUEUE_FULL = "queue_full"
REJECT_DEADLINE_UNMEETABLE = "deadline_unmeetable"
REJECT_UNKNOWN_MATRIX = "unknown_matrix"
REJECT_BAD_OPERAND = "bad_operand"
REJECT_FLEET_DEGRADED = "fleet_degraded"


@dataclasses.dataclass
class Request:
    """One admitted (or rejected) unit of work.  Mutated in place as it
    moves queued → running → done/expired/failed; the :class:`Ticket`
    handed back at submit time reads the same object."""

    id: int
    tenant: str
    matrix: str
    b: np.ndarray
    kind: str = "spmv"               # "spmv" (w = A v) | "solve" (CG)
    tol: float = 1e-10
    maxiter: int = 500
    deadline: float = float("inf")   # absolute service-clock time
    submitted_at: float = 0.0
    status: str = "queued"  # queued|running|done|expired|failed|rejected
    reason: Optional[str] = None     # reject/fail reason
    attempts: int = 0
    not_before: float = float("-inf")   # backoff gate
    x0: Optional[np.ndarray] = None     # restored warm start (recovery)
    result: Optional[np.ndarray] = None
    iters: int = 0
    completed_at: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Ticket:
    """Caller's handle on a request (live view — no polling protocol)."""

    request: Request

    @property
    def id(self) -> int:
        return self.request.id

    @property
    def status(self) -> str:
        return self.request.status

    @property
    def reason(self) -> Optional[str]:
        return self.request.reason

    def result(self) -> np.ndarray:
        if self.request.status != "done":
            raise ValueError(f"request {self.request.id} is "
                             f"{self.request.status} ({self.request.reason})")
        return self.request.result


def _colsum(M: np.ndarray) -> np.ndarray:
    """Per-column sums as independent contiguous 1-D reductions.  A
    blocked ``np.sum(M, axis=0)`` orders its accumulation by the array's
    width and strides, so the SAME column reduces differently in a k=1
    and a k=4 batch — which would break the batched-equals-solo
    bit-identity contract below.  Column-at-a-time sums don't."""
    return np.array([np.sum(np.ascontiguousarray(M[:, j]))
                     for j in range(M.shape[1])])


def batched_cg(mv: Callable, B: np.ndarray, tol: float = 1e-10,
               maxiter: int = 500, X0: Optional[np.ndarray] = None,
               callback: Optional[Callable[[int, np.ndarray], None]] = None):
    """Multi-RHS CG: one [n, k] iterate block, SHARED SpMVs.

    Each column runs an independent CG (every scalar is per-column and
    every reduction is column-at-a-time, see :func:`_colsum`), but the k
    systems pay ONE nv-tiled ``mv([n, k])`` per iteration — the batching
    win the service exists for.  Converged columns freeze (alpha=0), so
    under a columnwise ``mv`` a column's final iterate is bit-identical
    to the solo 1-RHS solve.  Returns ``(X, iters[k], relres[k])``.
    ``callback(it, X)`` fires per iteration — the checkpoint/fault seam.
    """
    B = np.asarray(B)
    X = np.zeros_like(B) if X0 is None else np.array(X0, dtype=B.dtype)
    R = B - mv(X)
    P = R.copy()
    rz = _colsum(R * R)
    b_norm = np.maximum(np.sqrt(_colsum(B * B)), 1e-30)
    rel = np.sqrt(_colsum(R * R)) / b_norm
    active = rel >= tol
    iters = np.zeros(B.shape[1], dtype=np.int64)
    for it in range(1, maxiter + 1):
        if not active.any():
            break
        AP = mv(P)
        pap = _colsum(P * AP)
        alpha = np.where(active, rz / np.maximum(np.abs(pap), 1e-300)
                         * np.sign(np.where(pap == 0, 1.0, pap)), 0.0)
        X = X + alpha * P
        R = R - alpha * AP
        if callback is not None:
            callback(it, X)
        rel = np.sqrt(_colsum(R * R)) / b_norm
        newly_done = active & (rel < tol)
        iters[newly_done] = it
        active = active & ~newly_done
        rz_new = _colsum(R * R)
        beta = np.where(active, rz_new / np.maximum(rz, 1e-300), 0.0)
        P = R + beta * P
        rz = rz_new
    iters[active] = maxiter
    return X, iters, rel


class SolverService:
    """See the module docstring for the lifecycle.  All configuration is
    constructor-time; ``step()`` advances the pump by one tick and
    ``run()`` pumps until the queue drains (bounded — never deadlocks)."""

    def __init__(self, topo: Topology, *, method: str = "nap",
                 backend: str = "simulate", local_compute: str = "auto",
                 queue_limit: int = 32, batch_limit: int = 8,
                 clock=None, dt: float = 1.0,
                 heartbeat_timeout: float = 2.5,
                 straggler_z: float = 1.0, straggler_rel: float = 1.5,
                 straggler_window: int = 8,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 4,
                 fault_plan: Optional[FaultPlan] = None,
                 max_attempts: int = 4, backoff: float = 1.0,
                 plan_cache_max: int = 8, mesh=None,
                 integrity: str = "off", quarantine_strikes: int = 3):
        self.clock = clock if clock is not None else ManualClock()
        self.dt = float(dt)
        self.topo = topo
        self.nodes: List[str] = [f"node{i}" for i in range(topo.n_nodes)]
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.monitor = HeartbeatMonitor(self.nodes, timeout=heartbeat_timeout,
                                        clock=self.clock)
        self._straggler_params = dict(window=straggler_window,
                                      z_thresh=straggler_z,
                                      rel_floor=straggler_rel)
        self.detector = StragglerDetector(**self._straggler_params)
        self.policy = ElasticPolicy()
        self.integrity = integrity
        self.quarantine_strikes = int(quarantine_strikes)
        self._pending_msg_faults: List[MessageFault] = []
        self._quarantine_pending: List[str] = []
        self.plans = PlanCache(topo, method=method, backend=backend,
                               local_compute=local_compute,
                               max_entries=plan_cache_max, mesh=mesh,
                               integrity=integrity)
        self.matrices: Dict[str, dict] = {}
        self.queue: "deque[Request]" = deque()
        self.requests: Dict[int, Request] = {}
        self._next_id = 0
        self.queue_limit = int(queue_limit)
        self.batch_limit = int(batch_limit)
        self.max_attempts = int(max_attempts)
        self.backoff = float(backoff)
        self.fault_plan = fault_plan if fault_plan is not None else FaultPlan()
        self.dead_now: set = set()          # scripted dead, not yet evicted
        self.slow_now: Dict[str, float] = {}
        self._midsolve_kill = None          # (node, at_iteration) armed
        self.degraded = False
        self.step_no = 0
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self.checkpoint_every = int(checkpoint_every)
        self._save_seq = 0
        self._torn_next_save = False
        self.tenants: Dict[str, dict] = {}
        self.stats: Dict[str, float] = {
            "steps": 0, "completed": 0, "rejected": 0, "expired": 0,
            "failed": 0, "retries": 0, "recoveries": 0, "torn_saves": 0,
            "message_faults": 0, "integrity_detected": 0, "quarantines": 0,
            "last_recover_rebuild_s": 0.0}
        self.log: List[str] = []

    # -- registration ------------------------------------------------------
    def register_matrix(self, name: str, a,
                        row_part: Optional[RowPartition] = None,
                        col_part: Optional[RowPartition] = None) -> None:
        """Register (or re-register) a named matrix for tenants to solve
        against.  Partitions default to contiguous over the CURRENT
        fleet; elastic recovery repartitions them in place."""
        if row_part is None:
            row_part = contiguous_partition(a.shape[0], self.topo.n_procs)
        if col_part is None:
            col_part = (row_part if a.shape[1] == row_part.n_rows
                        else contiguous_partition(a.shape[1],
                                                  self.topo.n_procs))
        self.matrices[name] = {"a": a, "row_part": row_part,
                               "col_part": col_part, "version": 0}

    def update_values(self, name: str, a_new) -> None:
        """Value-only update of a registered matrix (same sparsity).  The
        plan cache hot-swaps the compiled program on next use — no
        recompile, no retrace (asserted via ``plans.stats``)."""
        m = self.matrices[name]
        old = m["a"]
        if (tuple(a_new.shape) != tuple(old.shape)
                or not np.array_equal(a_new.indptr, old.indptr)
                or not np.array_equal(a_new.indices, old.indices)):
            raise ValueError(
                f"update_values({name!r}) changed the sparsity structure; "
                f"re-register the matrix instead")
        m["a"] = a_new
        m["version"] += 1

    # -- admission ---------------------------------------------------------
    def submit(self, tenant: str, matrix: str, b, *, kind: str = "spmv",
               tol: float = 1e-10, maxiter: int = 500,
               deadline: Optional[float] = None) -> Ticket:
        """Admit one request (or reject it with a reason — never block).

        ``deadline`` is an ABSOLUTE service-clock time; a request still
        queued past it is shed as ``expired``.  ``kind="spmv"`` returns
        ``A @ b``; ``kind="solve"`` returns CG's solution of ``A x = b``.
        """
        if kind not in ("spmv", "solve"):
            raise ValueError(f"kind must be spmv|solve, got {kind!r}")
        now = float(self.clock())
        self._next_id += 1
        req = Request(id=self._next_id, tenant=tenant, matrix=matrix,
                      b=np.asarray(b, dtype=np.float64), kind=kind, tol=tol,
                      maxiter=maxiter,
                      deadline=float("inf") if deadline is None
                      else float(deadline),
                      submitted_at=now)
        self.requests[req.id] = req
        acct = self._acct(tenant)
        acct["submitted"] += 1
        reason = None
        if self.degraded:
            reason = REJECT_FLEET_DEGRADED
        elif matrix not in self.matrices:
            reason = REJECT_UNKNOWN_MATRIX
        elif req.b.ndim != 1 or req.b.shape[0] != \
                self.matrices[matrix]["a"].shape[1 if kind == "spmv" else 0]:
            reason = REJECT_BAD_OPERAND
        elif req.deadline <= now:
            reason = REJECT_DEADLINE_UNMEETABLE
        elif len(self.queue) >= self.queue_limit:
            reason = REJECT_QUEUE_FULL
        if reason is not None:
            req.status, req.reason = "rejected", reason
            acct["rejected"] += 1
            self.stats["rejected"] += 1
            return Ticket(req)
        self.queue.append(req)
        return Ticket(req)

    # -- the pump ----------------------------------------------------------
    def step(self) -> Dict[str, object]:
        """One deterministic pump tick: clock → scripted faults →
        heartbeats → detection/recovery → deadline shedding → one batch
        execution.  Returns a small per-step report."""
        self.step_no += 1
        self.stats["steps"] += 1
        if hasattr(self.clock, "advance"):
            self.clock.advance(self.dt)
        now = float(self.clock())
        for ev in self.fault_plan.at(self.step_no):
            self._inject(ev)
        for n in self.nodes:
            if n in self.dead_now:
                continue             # dead nodes fall silent
            self.monitor.beat(n)
            self.detector.record(n, self.dt * self.slow_now.get(n, 1.0))
        evicted = sorted(set(self.monitor.dead_nodes())
                         | (set(self.detector.stragglers()) & set(self.nodes)))
        if evicted and not self.degraded:
            self._recover(evicted)
        self._shed_expired(now)
        executed = self._pump(now)
        if self._quarantine_pending and not self.degraded:
            cand = [n for n in self._quarantine_pending if n in self.nodes]
            self._quarantine_pending = []
            if cand:
                self.stats["quarantines"] += 1
                self.log.append(
                    f"step {self.step_no}: quarantining {cand} after "
                    f">={self.quarantine_strikes} integrity strikes")
                self._recover(cand)
                evicted = sorted(set(evicted) | set(cand))
        return {"step": self.step_no, "now": now, "executed": executed,
                "queued": len(self.queue), "evicted": evicted}

    def run(self, max_steps: int = 1000) -> int:
        """Pump until the queue drains or ``max_steps`` elapse (a hard
        bound — a wedged workload terminates with requests still queued
        rather than deadlocking).  Returns the number of steps taken."""
        for i in range(1, max_steps + 1):
            self.step()
            if not self.queue:
                return i
        return max_steps

    # -- internals ---------------------------------------------------------
    def _acct(self, tenant: str) -> dict:
        return self.tenants.setdefault(
            tenant, {"submitted": 0, "completed": 0, "rejected": 0,
                     "expired": 0, "failed": 0, "retries": 0,
                     "spmv_applies": 0, "cg_iters": 0, "plan": {}})

    def _inject(self, ev) -> None:
        if ev.kind == "dead_node":
            if ev.at_iteration is not None:
                self._midsolve_kill = (ev.node, int(ev.at_iteration))
                self.log.append(f"step {self.step_no}: armed mid-solve kill "
                                f"of {ev.node} at CG iteration "
                                f"{ev.at_iteration}")
            else:
                self.dead_now.add(ev.node)
                self.log.append(f"step {self.step_no}: {ev.node} died")
        elif ev.kind == "straggler":
            self.slow_now[ev.node] = ev.slowdown
            self.log.append(f"step {self.step_no}: {ev.node} straggling "
                            f"{ev.slowdown}x")
        elif ev.kind == "torn_checkpoint":
            self._torn_next_save = True
            self.log.append(f"step {self.step_no}: next checkpoint save "
                            f"will tear")
        elif ev.kind in ("corrupt_message", "drop_message",
                         "duplicate_message"):
            self.stats["message_faults"] += 1
            if self.integrity == "off":
                self.log.append(
                    f"step {self.step_no}: scripted {ev.kind} dropped — "
                    f"no integrity layer on this service (the corruption "
                    f"would have gone undetected)")
            else:
                self._pending_msg_faults.append(ev.fault)
                f = ev.fault
                self.log.append(
                    f"step {self.step_no}: scripted {ev.kind} armed "
                    f"(phase={f.phase} kind={f.kind} sender="
                    f"({f.node},{f.proc}) slot={f.slot})")

    def _shed_expired(self, now: float) -> None:
        keep = deque()
        for r in self.queue:
            if r.deadline <= now:
                r.status, r.reason = "expired", "deadline passed in queue"
                self._acct(r.tenant)["expired"] += 1
                self.stats["expired"] += 1
            else:
                keep.append(r)
        self.queue = keep

    def _pump(self, now: float) -> int:
        """Execute ONE earliest-deadline batch of ready requests."""
        ready = [r for r in self.queue if r.not_before <= now]
        if not ready:
            return 0
        ready.sort(key=lambda r: (r.deadline, r.id))
        head = ready[0]
        batch = [r for r in ready
                 if r.matrix == head.matrix and r.kind == head.kind
                 ][: self.batch_limit]
        for r in batch:
            self.queue.remove(r)
            r.status = "running"
        try:
            self._execute(batch, now)
        except (FabricError, IntegrityError) as e:
            if isinstance(e, IntegrityError):
                self.stats["integrity_detected"] += 1
            for r in batch:
                r.attempts += 1
                if r.attempts >= self.max_attempts:
                    r.status, r.reason = "failed", str(e)
                    self._acct(r.tenant)["failed"] += 1
                    self.stats["failed"] += 1
                else:
                    r.status = "queued"
                    r.not_before = now + self._backoff_delay(r.id, r.attempts)
                    self.queue.append(r)
                    self._acct(r.tenant)["retries"] += 1
                    self.stats["retries"] += 1
            kind = ("integrity" if isinstance(e, IntegrityError)
                    else "fabric")
            self.log.append(f"step {self.step_no}: batch of {len(batch)} "
                            f"hit {kind} error: {e}")
        return len(batch)

    def _backoff_delay(self, request_id: int, attempt: int) -> float:
        """Exponential backoff with DETERMINISTIC seeded jitter.  A bare
        ``backoff * 2**(attempt-1)`` synchronizes every request failed in
        the same step onto the same retry step — a thundering herd at
        exactly the moment the fleet is recovering.  The jitter spreads
        them over [1x, 1.25x] of the base delay, derived from
        (request id, attempt) so fault scenarios replay exactly."""
        base = self.backoff * 2 ** (attempt - 1)
        digest = hashlib.sha256(f"{request_id}:{attempt}".encode()).digest()
        jitter = int.from_bytes(digest[:8], "big") / 2.0 ** 64
        return base * (1.0 + 0.25 * jitter)

    def _execute(self, batch: List[Request], now: float) -> None:
        m = self.matrices[batch[0].matrix]
        op = self.plans.operator_for(m["a"], m["row_part"], m["col_part"])
        if self.dead_now:
            raise FabricError(f"collective timed out: "
                              f"{sorted(self.dead_now)} unreachable")
        if self._pending_msg_faults:
            for f in self._pending_msg_faults:
                # a fault scripted against coordinates the fleet no longer
                # has (sender evicted since it was armed) cannot fire
                if f.node >= self.topo.n_nodes or f.proc >= self.topo.ppn:
                    self.log.append(
                        f"step {self.step_no}: scripted fault on evicted "
                        f"sender ({f.node},{f.proc}) dropped")
                    continue
                op.queue_fault(f)
            self._pending_msg_faults = []
        V = np.stack([r.b for r in batch], axis=1)
        if batch[0].kind == "spmv":
            W = op @ V
            iters = np.zeros(len(batch), dtype=np.int64)
            rel = np.zeros(len(batch))
        else:
            X0 = None
            if any(r.x0 is not None for r in batch):
                X0 = np.stack(
                    [r.x0 if r.x0 is not None else np.zeros_like(r.b)
                     for r in batch], axis=1)
            cb = self._solve_callback(batch)
            W, iters, rel = batched_cg(
                op, V, tol=min(r.tol for r in batch),
                maxiter=max(r.maxiter for r in batch), X0=X0, callback=cb)
        for i, r in enumerate(batch):
            r.status = "done"
            r.result = np.ascontiguousarray(W[:, i])
            r.iters = int(iters[i])
            r.completed_at = float(self.clock())
            acct = self._acct(r.tenant)
            acct["completed"] += 1
            acct["spmv_applies"] += 1 if r.kind == "spmv" else int(iters[i]) + 1
            acct["cg_iters"] += int(iters[i])
            for k, v in op.stats().items():
                if dataclasses.is_dataclass(v):   # PhaseStats and friends
                    for f in dataclasses.fields(v):
                        x = getattr(v, f.name)
                        if isinstance(x, (int, float)):
                            kk = f"{k}.{f.name}"
                            acct["plan"][kk] = acct["plan"].get(kk, 0) + x
                elif isinstance(v, (int, float)):
                    acct["plan"][k] = acct["plan"].get(k, 0) + v
            self.stats["completed"] += 1
        if self.integrity == "recover":
            # k strikes against a node (attributed by the wire checksums)
            # propose it to the elastic path — a link that corrupts
            # repeatedly is treated like a failing node.
            strikes = op.integrity_report().get("strikes", {})
            cand = sorted(n for n, s in strikes.items()
                          if s >= self.quarantine_strikes and n in self.nodes)
            if cand:
                self._quarantine_pending = cand

    def _solve_callback(self, batch: List[Request]) -> Callable:
        ids = np.array([r.id for r in batch], dtype=np.int64)
        name = batch[0].matrix
        version = self.matrices[name]["version"]

        def cb(it: int, X: np.ndarray) -> None:
            if self.ckpt is not None and it % self.checkpoint_every == 0:
                self._save_solver_state(name, version, ids, it, X)
            if self._midsolve_kill is not None:
                node, at_it = self._midsolve_kill
                if it >= at_it:
                    self._midsolve_kill = None
                    self.dead_now.add(node)
                    self.log.append(f"step {self.step_no}: {node} died "
                                    f"mid-solve at CG iteration {it}")
                    raise FabricError(f"{node} died mid-solve "
                                      f"(iteration {it})")
        return cb

    def _save_solver_state(self, name: str, version: int, ids: np.ndarray,
                           it: int, X: np.ndarray) -> None:
        self._save_seq += 1
        hook = None
        if self._torn_next_save:
            self._torn_next_save = False

            def hook():
                raise OSError("scripted torn checkpoint: writer killed "
                              "before _COMMITTED")
        try:
            self.ckpt.save(self._save_seq, {"x": np.asarray(X), "ids": ids},
                           extra={"matrix": name, "version": version,
                                  "iteration": it},
                           block=True, on_before_commit=hook)
        except RuntimeError as e:
            self.stats["torn_saves"] += 1
            self.log.append(f"step {self.step_no}: checkpoint save "
                            f"{self._save_seq} failed ({e.__cause__}); "
                            f"previous committed step stands")

    def _recover(self, evicted: List[str]) -> None:
        """The elastic path: survivor topology → repartition → plan
        rebuild → checkpoint restore → requeue in-flight requests."""
        t0 = time.perf_counter()
        new_topo = self.policy.survivor_topology(
            self.topo, [self.nodes.index(n) for n in evicted])
        if new_topo is None:
            self.degraded = True
            while self.queue:
                r = self.queue.popleft()
                r.status, r.reason = "failed", REJECT_FLEET_DEGRADED
                self._acct(r.tenant)["failed"] += 1
                self.stats["failed"] += 1
            self.log.append(f"step {self.step_no}: fleet fully degraded "
                            f"({evicted} evicted, nobody left)")
            return
        dead_ranks = sorted(
            r for n in evicted
            for r in self.topo.ranks_on_node(self.nodes.index(n)))
        for m in self.matrices.values():
            same = m["col_part"] is m["row_part"]
            m["row_part"] = survivor_partition(m["row_part"], dead_ranks)
            m["col_part"] = (m["row_part"] if same else
                             survivor_partition(m["col_part"], dead_ranks))
        dropped = self.plans.rebuild(new_topo)
        survivors = [n for n in self.nodes if n not in set(evicted)]
        self.nodes = survivors
        self.topo = new_topo
        self.dead_now -= set(evicted)
        for n in evicted:
            self.slow_now.pop(n, None)
        self.monitor = HeartbeatMonitor(self.nodes,
                                        timeout=self.heartbeat_timeout,
                                        clock=self.clock)
        self.detector = StragglerDetector(**self._straggler_params)
        # eager recompile so the rebuild cost lands here, not on the next
        # tenant request (and so last_recover_rebuild_s measures it)
        for m in self.matrices.values():
            self.plans.operator_for(m["a"], m["row_part"], m["col_part"])
        self._restore_solver_state()
        now = float(self.clock())
        for r in self.queue:      # in-flight retries re-execute immediately
            if r.attempts > 0:
                r.not_before = now
        self.stats["recoveries"] += 1
        self.stats["last_recover_rebuild_s"] = time.perf_counter() - t0
        self.log.append(
            f"step {self.step_no}: evicted {evicted}, rebuilt {dropped} "
            f"plans on {new_topo.n_nodes}x{new_topo.ppn}, "
            f"{len(self.matrices)} matrices repartitioned")

    def _restore_solver_state(self) -> None:
        if self.ckpt is None:
            return
        try:
            tree, extra = self.ckpt.restore()
        except FileNotFoundError:
            return                      # nothing committed yet
        name, version = extra.get("matrix"), extra.get("version")
        m = self.matrices.get(name)
        if m is None or m["version"] != version:
            return                      # stale values: cold-start instead
        by_id = {int(i): k for k, i in enumerate(np.asarray(tree["ids"]))}
        X = np.asarray(tree["x"])
        restored = 0
        for r in self.queue:
            col = by_id.get(r.id)
            if col is not None and r.kind == "solve" and r.matrix == name:
                r.x0 = np.ascontiguousarray(X[:, col])
                restored += 1
        if restored:
            self.log.append(
                f"step {self.step_no}: restored checkpointed iterates "
                f"(iteration {extra.get('iteration')}) for {restored} "
                f"in-flight solves")

    # -- introspection -----------------------------------------------------
    def report(self) -> Dict[str, object]:
        """Service-level stats + per-tenant accounting + plan-cache
        counters, one dict (the ops surface)."""
        return {"stats": dict(self.stats),
                "plan_cache": dict(self.plans.stats),
                "tenants": {t: dict(v) for t, v in self.tenants.items()},
                "fleet": {"nodes": list(self.nodes),
                          "topo": (self.topo.n_nodes, self.topo.ppn),
                          "degraded": self.degraded},
                "queue_depth": len(self.queue)}
