"""Structure-keyed plan cache with hot value swaps.

The global compile cache in :mod:`repro.core.spmv_jax` keys on the full
matrix — **including values** — because a compiled plan eagerly carries
value arrays.  A long-lived service re-solving the same sparsity with
evolving coefficients (time stepping, Newton updates, per-tenant
variants) would miss that cache on every value change and pay a full
replan + retrace.

:class:`PlanCache` keys on STRUCTURE alone — sparsity pattern, partition
owners, topology, executor configuration — and keeps a values
fingerprint per entry:

* same structure, same values  → plain hit, the cached operator returns;
* same structure, new values   → **hot swap**: ``op.swap_values`` rebuilds
  the value arrays in place and the compiled program re-runs with zero
  retraces (value arrays are jit arguments — see
  :data:`repro.core.spmv_jax.VALUE_ARRAY_NAMES`); counted under
  ``stats["hot_swaps"]``;
* new structure                → miss, a fresh operator compiles.

``rebuild(new_topo)`` is the elastic path: every cached plan is stale
the moment the node layout changes (the paper's premise — comm plans are
functions of the topology), so the cache drops them wholesale and
retargets its factory at the survivor topology.

Device-buffer lifecycle: every compiled plan pins its mesh-shaped
arrays in a :mod:`repro.mesh.buffers` registry namespace.  LRU eviction
and elastic rebuilds RELEASE those namespaces explicitly (the bytes
show up in the registry's eviction stats, surfaced via
:meth:`PlanCache.buffer_report`) instead of waiting on the collector.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro.core.partition import RowPartition
from repro.core.topology import Topology


def structure_key(a, row_part: RowPartition, col_part: RowPartition,
                  topo: Topology, method: str, backend: str,
                  local_compute: str = "auto", integrity: str = "off") -> str:
    """Digest of everything a compiled plan depends on EXCEPT the matrix
    values — two matrices with equal keys may hot-swap into each other's
    compiled program.  ``integrity`` keys too: the instrumented program
    is a different jit signature than the bare one."""
    h = hashlib.sha1()
    for arr in (a.indptr, a.indices, row_part.owner, col_part.owner):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(repr((tuple(a.shape), topo.n_nodes, topo.ppn,
                   method, backend, local_compute, integrity)).encode())
    return h.hexdigest()


def values_fingerprint(a) -> str:
    """Digest of the matrix values alone (hot-swap change detection)."""
    return hashlib.sha1(np.ascontiguousarray(a.data).tobytes()).hexdigest()


def release_operator_buffers(op) -> int:
    """Release every device-buffer namespace an operator's executors pin
    (forward AND transpose, when split).  Returns bytes released; safe on
    simulate-backend operators (which pin nothing)."""
    freed = 0
    for ex in (getattr(op, "executor", None),
               getattr(op, "transpose_executor", None)):
        cache = getattr(getattr(ex, "_compiled", None), "_dev_cache", None)
        release = getattr(cache, "release", None)
        if release is not None:
            freed += release()
    return freed


class PlanCache:
    """LRU cache of live :class:`repro.api.NapOperator`s, structure-keyed."""

    def __init__(self, topo: Topology, *, method: str = "nap",
                 backend: str = "simulate", local_compute: str = "auto",
                 max_entries: int = 8, mesh=None, integrity: str = "off",
                 **operator_kwargs):
        self.topo = topo
        self.method, self.backend = method, backend
        self.local_compute = local_compute
        self.max_entries = int(max_entries)
        self.mesh = mesh
        self.integrity = integrity
        self.operator_kwargs = dict(operator_kwargs)
        self._entries: "OrderedDict[str, dict]" = OrderedDict()
        self.stats: Dict[str, int] = {"hits": 0, "misses": 0, "hot_swaps": 0,
                                      "evictions": 0, "rebuilds": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def operator_for(self, a, row_part: RowPartition,
                     col_part: Optional[RowPartition] = None):
        """The cached operator for (structure, layout), values current.

        A structural hit with changed values hot-swaps in place; the
        caller gets a ready operator either way and never recompiles for
        a pure value update.
        """
        cpart = row_part if col_part is None else col_part
        key = structure_key(a, row_part, cpart, self.topo,
                            self.method, self.backend, self.local_compute,
                            self.integrity)
        ent = self._entries.get(key)
        if ent is not None:
            self._entries.move_to_end(key)
            fp = values_fingerprint(a)
            if fp != ent["fingerprint"]:
                ent["op"].swap_values(a)
                ent["fingerprint"] = fp
                self.stats["hot_swaps"] += 1
            else:
                self.stats["hits"] += 1
            return ent["op"]
        self.stats["misses"] += 1
        import repro.api as nap
        op = nap.operator(a, topo=self.topo, row_part=row_part,
                          col_part=cpart, method=self.method,
                          backend=self.backend,
                          local_compute=self.local_compute, mesh=self.mesh,
                          integrity=self.integrity, **self.operator_kwargs)
        while len(self._entries) >= self.max_entries:
            _, old = self._entries.popitem(last=False)
            self.stats["buffer_bytes_released"] = (
                self.stats.get("buffer_bytes_released", 0)
                + release_operator_buffers(old["op"]))
            self.stats["evictions"] += 1
        self._entries[key] = {"op": op, "fingerprint": values_fingerprint(a)}
        return op

    def rebuild(self, new_topo: Topology) -> int:
        """Elastic rebuild: drop EVERY cached plan (all are stale on a
        changed topology) and retarget the factory at ``new_topo``.
        Returns the number of plans dropped; subsequent ``operator_for``
        calls recompile against the survivor layout."""
        dropped = len(self._entries)
        for ent in self._entries.values():
            self.stats["buffer_bytes_released"] = (
                self.stats.get("buffer_bytes_released", 0)
                + release_operator_buffers(ent["op"]))
        self._entries.clear()
        self.topo = new_topo
        self.mesh = None   # a mesh built for the old fleet shape is stale too
        self.stats["rebuilds"] += 1
        return dropped

    def buffer_report(self) -> Dict[str, object]:
        """The process-wide buffer registry's accounting (staged/reused/
        evicted counts and bytes, live namespaces, resident bytes)."""
        from repro.mesh.buffers import default_registry
        return default_registry().report()
