"""Fault-tolerant persistent solver service over the NAP operator stack.

Public surface::

    from repro.serve import SolverService, FaultPlan, dead_node, ManualClock

    svc = SolverService(topo, backend="simulate",
                        fault_plan=FaultPlan.of(dead_node(3, "node1")))
    svc.register_matrix("poisson", A)
    t = svc.submit("tenant-a", "poisson", b, kind="solve", deadline=50.0)
    svc.run()
    x = t.result()

See ``src/repro/serve/README.md`` for the lifecycle (admit → batch →
solve → recover), the fault-injection DSL, and plan-cache keying.
"""
from repro.serve.faultplan import (FabricError, FaultEvent, FaultPlan,
                                   ManualClock, dead_node, straggler,
                                   torn_checkpoint)
from repro.serve.plancache import PlanCache, structure_key, values_fingerprint
from repro.serve.service import (REJECT_BAD_OPERAND,
                                 REJECT_DEADLINE_UNMEETABLE,
                                 REJECT_FLEET_DEGRADED, REJECT_QUEUE_FULL,
                                 REJECT_UNKNOWN_MATRIX, Request, SolverService,
                                 Ticket, batched_cg)

__all__ = [
    "SolverService", "Request", "Ticket", "batched_cg",
    "PlanCache", "structure_key", "values_fingerprint",
    "FaultPlan", "FaultEvent", "FabricError", "ManualClock",
    "dead_node", "straggler", "torn_checkpoint",
    "REJECT_QUEUE_FULL", "REJECT_DEADLINE_UNMEETABLE",
    "REJECT_UNKNOWN_MATRIX", "REJECT_BAD_OPERAND", "REJECT_FLEET_DEGRADED",
]
