"""Deterministic fault injection for the solver service.

A :class:`FaultPlan` is a script of :class:`FaultEvent`s keyed on the
service's step counter — the service pump consults it at every step
boundary, so a given (plan, workload) pair replays IDENTICALLY run after
run.  Faults act through the clock-injectable production scaffolding, not
through test monkey-patching:

* ``dead_node(step, node)`` — the node stops heartbeating at ``step``;
  :class:`repro.runtime.fault.HeartbeatMonitor` times it out and the
  service's elastic recovery evicts it.  ``at_iteration=k`` delays the
  death until an in-flight solve reaches CG iteration k (the scripted
  *mid-solve* loss).  While a dead node is in the fleet, every collective
  raises :class:`FabricError` — exactly how a real all-to-all fails.
* ``straggler(step, node, slowdown)`` — the node starts reporting
  ``slowdown``× step times; :class:`repro.runtime.fault.
  StragglerDetector` flags it and the service evicts it through the same
  recovery path as a death.
* ``torn_checkpoint(step)`` — the NEXT checkpoint save dies between the
  shard files and the ``_COMMITTED`` marker (via ``save_checkpoint``'s
  ``on_before_commit`` hook); restore must fall back to the previous
  committed step.
* ``corrupt_message(step, edge)`` / ``drop_message`` /
  ``duplicate_message`` — DATA-plane faults: the scripted
  :class:`repro.core.integrity.MessageFault` is queued onto the serving
  operator at ``step`` and fires inside the next SpMV apply as a pure
  transform at the pack boundary (bitflip / zeroed / stale / dropped /
  duplicated payload on one exchange message).  What happens next is the
  operator's ``integrity`` mode: ``"detect"`` raises with phase+message
  attribution, ``"recover"`` retries clean and counts a strike against
  the implicated node.

``FaultPlan.random(seed, ...)`` draws a scripted plan from a seeded
generator: same seed, same plan, same eviction step — the determinism
the crash-consistency tests assert.  Pass ``ppn=`` to include the
message-fault kinds (they need sender device coordinates).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.integrity import MessageFault, NAP_MESSAGE_PHASES


class FabricError(RuntimeError):
    """A collective failed because a fleet member is unreachable."""


class ManualClock:
    """Deterministic injectable clock: ``clock()`` reads, ``advance``
    moves time forward.  Drop-in for ``time.monotonic`` everywhere the
    runtime scaffolding accepts a ``clock`` callable."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += float(dt)
        return self.t


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault, triggered when the service pump reaches
    ``step``.  ``node`` names the victim for dead_node/straggler;
    ``at_iteration`` (dead_node only) defers the death until an in-flight
    solve reaches that CG iteration; ``fault`` carries the scripted
    :class:`MessageFault` for the message kinds."""

    step: int
    kind: str                      # dead_node | straggler | torn_checkpoint
    node: Optional[str] = None     # | corrupt/drop/duplicate_message
    slowdown: float = 1.0
    at_iteration: Optional[int] = None
    fault: Optional[MessageFault] = None

    KINDS = ("dead_node", "straggler", "torn_checkpoint",
             "corrupt_message", "drop_message", "duplicate_message")
    MESSAGE_KINDS = ("corrupt_message", "drop_message", "duplicate_message")

    def __post_init__(self) -> None:
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")
        if self.kind in self.MESSAGE_KINDS:
            if self.fault is None:
                raise ValueError(f"{self.kind} needs a MessageFault payload")
        elif self.kind != "torn_checkpoint" and self.node is None:
            raise ValueError(f"{self.kind} needs a target node")


def dead_node(step: int, node: str,
              at_iteration: Optional[int] = None) -> FaultEvent:
    """Node death at ``step`` (optionally mid-solve at CG iteration k)."""
    return FaultEvent(step=step, kind="dead_node", node=node,
                      at_iteration=at_iteration)


def straggler(step: int, node: str, slowdown: float = 4.0) -> FaultEvent:
    """Node starts running ``slowdown``× slow at ``step``."""
    return FaultEvent(step=step, kind="straggler", node=node,
                      slowdown=float(slowdown))


def torn_checkpoint(step: int) -> FaultEvent:
    """The next checkpoint save after ``step`` tears before commit."""
    return FaultEvent(step=step, kind="torn_checkpoint")


Edge = Tuple[str, Union[int, Tuple[int, int]], int]


def _edge_fault(edge: Edge, kind: str, element: int, bit: int,
                direction: str) -> MessageFault:
    """``edge = (phase, sender, slot)`` — sender as (node, proc) device
    coordinates or a flat rank."""
    phase, sender, slot = edge
    if not isinstance(sender, tuple):
        raise ValueError("pass the sender as (node, proc) device "
                         "coordinates; a flat rank needs the topology's "
                         "ppn to split")
    node, proc = sender
    return MessageFault(phase=phase, kind=kind, node=int(node),
                        proc=int(proc), slot=int(slot), element=int(element),
                        bit=int(bit), direction=direction)


def corrupt_message(step: int, edge: Edge, kind: str = "bitflip",
                    element: int = 0, bit: int = 30,
                    direction: str = "forward") -> FaultEvent:
    """Corrupt ONE exchange message at ``step``: ``kind`` is
    ``"bitflip"`` | ``"zero"`` | ``"stale"``; ``edge`` is
    ``(phase, (node, proc), slot)`` — the sending device and destination
    message slot within the phase."""
    if kind not in ("bitflip", "zero", "stale"):
        raise ValueError(f"corrupt_message kind must be bitflip|zero|stale, "
                         f"got {kind!r} (use drop_message / "
                         f"duplicate_message for the other kinds)")
    return FaultEvent(step=step, kind="corrupt_message",
                      fault=_edge_fault(edge, kind, element, bit, direction))


def drop_message(step: int, edge: Edge,
                 direction: str = "forward") -> FaultEvent:
    """Drop ONE exchange message at ``step`` (the receiver sees a zeroed
    payload — the static-SPMD model of a lost send)."""
    return FaultEvent(step=step, kind="drop_message",
                      fault=_edge_fault(edge, "drop", 0, 0, direction))


def duplicate_message(step: int, edge: Edge,
                      direction: str = "forward") -> FaultEvent:
    """Deliver a DIFFERENT message from the same sender in place of this
    one (payload duplication / misrouting)."""
    return FaultEvent(step=step, kind="duplicate_message",
                      fault=_edge_fault(edge, "duplicate", 0, 0, direction))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable script of fault events, consulted per service step."""

    events: Tuple[FaultEvent, ...] = ()

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def __len__(self) -> int:
        return len(self.events)

    @staticmethod
    def of(*events: FaultEvent) -> "FaultPlan":
        return FaultPlan(events=tuple(sorted(events, key=lambda e: e.step)))

    @staticmethod
    def random(seed: int, nodes: Sequence[str], n_steps: int,
               n_events: int = 1, ppn: Optional[int] = None) -> "FaultPlan":
        """Seeded random plan over ``nodes`` within ``n_steps``.  Pure
        function of its arguments: same seed → same events, same steps,
        same corrupted edges — the determinism contract the tests pin
        down.  With ``ppn`` set the draw includes the message-fault
        kinds (sender device coordinates need the node width)."""
        rng = np.random.default_rng(seed)
        kinds = FaultEvent.KINDS if ppn else \
            tuple(k for k in FaultEvent.KINDS
                  if k not in FaultEvent.MESSAGE_KINDS)
        events = []
        for _ in range(n_events):
            kind = str(rng.choice(kinds))
            step = int(rng.integers(1, max(2, n_steps)))
            if kind == "torn_checkpoint":
                events.append(torn_checkpoint(step))
            elif kind == "straggler":
                events.append(straggler(step, str(rng.choice(list(nodes))),
                                        slowdown=float(rng.integers(3, 8))))
            elif kind in FaultEvent.MESSAGE_KINDS:
                edge = (str(rng.choice(NAP_MESSAGE_PHASES)),
                        (int(rng.integers(0, len(nodes))),
                         int(rng.integers(0, ppn))),
                        int(rng.integers(0, max(len(nodes), ppn))))
                if kind == "corrupt_message":
                    events.append(corrupt_message(
                        step, edge,
                        kind=str(rng.choice(("bitflip", "zero", "stale"))),
                        element=int(rng.integers(0, 64)),
                        bit=int(rng.integers(0, 31))))
                elif kind == "drop_message":
                    events.append(drop_message(step, edge))
                else:
                    events.append(duplicate_message(step, edge))
            else:
                events.append(dead_node(step, str(rng.choice(list(nodes)))))
        return FaultPlan.of(*events)
