"""Deterministic fault injection for the solver service.

A :class:`FaultPlan` is a script of :class:`FaultEvent`s keyed on the
service's step counter — the service pump consults it at every step
boundary, so a given (plan, workload) pair replays IDENTICALLY run after
run.  Faults act through the clock-injectable production scaffolding, not
through test monkey-patching:

* ``dead_node(step, node)`` — the node stops heartbeating at ``step``;
  :class:`repro.runtime.fault.HeartbeatMonitor` times it out and the
  service's elastic recovery evicts it.  ``at_iteration=k`` delays the
  death until an in-flight solve reaches CG iteration k (the scripted
  *mid-solve* loss).  While a dead node is in the fleet, every collective
  raises :class:`FabricError` — exactly how a real all-to-all fails.
* ``straggler(step, node, slowdown)`` — the node starts reporting
  ``slowdown``× step times; :class:`repro.runtime.fault.
  StragglerDetector` flags it and the service evicts it through the same
  recovery path as a death.
* ``torn_checkpoint(step)`` — the NEXT checkpoint save dies between the
  shard files and the ``_COMMITTED`` marker (via ``save_checkpoint``'s
  ``on_before_commit`` hook); restore must fall back to the previous
  committed step.

``FaultPlan.random(seed, ...)`` draws a scripted plan from a seeded
generator: same seed, same plan, same eviction step — the determinism
the crash-consistency tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


class FabricError(RuntimeError):
    """A collective failed because a fleet member is unreachable."""


class ManualClock:
    """Deterministic injectable clock: ``clock()`` reads, ``advance``
    moves time forward.  Drop-in for ``time.monotonic`` everywhere the
    runtime scaffolding accepts a ``clock`` callable."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += float(dt)
        return self.t


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault, triggered when the service pump reaches
    ``step``.  ``node`` names the victim for dead_node/straggler;
    ``at_iteration`` (dead_node only) defers the death until an in-flight
    solve reaches that CG iteration."""

    step: int
    kind: str                      # dead_node | straggler | torn_checkpoint
    node: Optional[str] = None
    slowdown: float = 1.0
    at_iteration: Optional[int] = None

    KINDS = ("dead_node", "straggler", "torn_checkpoint")

    def __post_init__(self) -> None:
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")
        if self.kind != "torn_checkpoint" and self.node is None:
            raise ValueError(f"{self.kind} needs a target node")


def dead_node(step: int, node: str,
              at_iteration: Optional[int] = None) -> FaultEvent:
    """Node death at ``step`` (optionally mid-solve at CG iteration k)."""
    return FaultEvent(step=step, kind="dead_node", node=node,
                      at_iteration=at_iteration)


def straggler(step: int, node: str, slowdown: float = 4.0) -> FaultEvent:
    """Node starts running ``slowdown``× slow at ``step``."""
    return FaultEvent(step=step, kind="straggler", node=node,
                      slowdown=float(slowdown))


def torn_checkpoint(step: int) -> FaultEvent:
    """The next checkpoint save after ``step`` tears before commit."""
    return FaultEvent(step=step, kind="torn_checkpoint")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable script of fault events, consulted per service step."""

    events: Tuple[FaultEvent, ...] = ()

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def __len__(self) -> int:
        return len(self.events)

    @staticmethod
    def of(*events: FaultEvent) -> "FaultPlan":
        return FaultPlan(events=tuple(sorted(events, key=lambda e: e.step)))

    @staticmethod
    def random(seed: int, nodes: Sequence[str], n_steps: int,
               n_events: int = 1) -> "FaultPlan":
        """Seeded random plan over ``nodes`` within ``n_steps``.  Pure
        function of its arguments: same seed → same events, same steps —
        the determinism contract the tests pin down."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = str(rng.choice(FaultEvent.KINDS))
            step = int(rng.integers(1, max(2, n_steps)))
            if kind == "torn_checkpoint":
                events.append(torn_checkpoint(step))
            elif kind == "straggler":
                events.append(straggler(step, str(rng.choice(list(nodes))),
                                        slowdown=float(rng.integers(3, 8))))
            else:
                events.append(dead_node(step, str(rng.choice(list(nodes)))))
        return FaultPlan.of(*events)
