"""Communication plans for the node-aware distributed SpGEMM ``C = A @ B``.

The paper's insight — aggregate off-node traffic per *node*, not per
process — transfers verbatim from SpMV to sparse matrix-matrix products
(Bienz et al., "Reducing Communication in Algebraic Multigrid with
Multi-step Node Aware Communication", arXiv:1904.05838): the AMG setup's
Galerkin triple products need exactly the rows of ``B`` that an SpMV
would need *entries* of ``x``.  Rank r computes the C rows of its A rows
(the ROW partition) and therefore needs B row k for every off-process
column k of its local A — the same (receiver, owner, index) set the SpMV
comm graphs of :mod:`repro.core.comm_graph` realise, with the vector
index j reinterpreted as the B-row id k.

We therefore REUSE the SpMV plan machinery unchanged — the standard plan
(Algorithm 1) and the three-step node-aware plan (on-process / on-node
gather / ONE aggregated inter-node exchange / on-node scatter) — and
change only the *payload*: each message slot carries the variable-length
CSR rows (indptr/indices/data triples) of the B rows it names, padded to
a compile-time value budget per phase, instead of one scalar per index.
Row *structure* (indices + counts) is exchanged once at plan-build time
("as the matrix is formed", Sec. 2.1 — exactly when MPI codes exchange
their send lists); only the VALUES flow through the runtime three-step.

:class:`SpGemmPlan` wraps the underlying SpMV plan plus the value-level
bookkeeping: per-phase value budgets and sorted row -> (start, count)
slot maps over each phase's flat padded value buffer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.comm_graph import (Message, NAPPlan, PhaseStats, StandardPlan,
                                   build_nap_plan, build_standard_plan)
from repro.core.partition import RowPartition
from repro.core.topology import Topology
from repro.sparse.csr import CSR, expand_positions

__all__ = ["SpGemmPlan", "build_spgemm_plan", "value_slot_map",
           "lookup_row_starts", "local_value_index", "expand_positions",
           "message_value_size", "phase_value_pad"]


def value_slot_map(msgs: Sequence[Message], slots: Sequence[int],
                   b_counts: np.ndarray, vpad: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Sorted row-id -> flat value-buffer START position for one phase.

    The value-level analogue of :func:`repro.core.comm_graph.flat_slot_map`:
    message i lands in buffer slot ``slots[i]``; its rows' values are
    concatenated in ``m.idx`` order, so row ``m.idx[t]`` starts at flat
    position ``slots[i] * vpad + sum(b_counts[m.idx[:t]])`` and spans
    ``b_counts[m.idx[t]]`` values.  Returns parallel ``(row, start)``
    arrays with ``row`` ascending (one ``np.searchsorted`` resolves whole
    row-id arrays).  Rows must be disjoint across the phase's messages.
    """
    if not msgs:
        z = np.empty(0, dtype=np.int64)
        return z, z.copy()
    rows = np.concatenate([m.idx for m in msgs])
    starts = np.concatenate([
        s * vpad + np.concatenate([[0], np.cumsum(b_counts[m.idx])[:-1]])
        for s, m in zip(slots, msgs)])
    order = np.argsort(rows, kind="stable")
    rows, starts = rows[order], starts[order]
    assert rows.size < 2 or (np.diff(rows) > 0).all(), \
        "phase delivers one B row through two messages"
    return rows, starts.astype(np.int64)


def lookup_row_starts(table: Tuple[np.ndarray, np.ndarray],
                      query: np.ndarray) -> np.ndarray:
    """Resolve row ids against a :func:`value_slot_map` table (the same
    sorted-parallel-array lookup as the SpMV slot maps)."""
    from repro.core.comm_graph import lookup_slots
    return lookup_slots(table, query)


def message_value_size(msg: Message, b_counts: np.ndarray) -> int:
    """Number of B values one message carries (sum of its rows' nnz)."""
    return int(b_counts[msg.idx].sum())


def phase_value_pad(msg_lists: List[List[Message]],
                    b_counts: np.ndarray) -> int:
    """Compile-time value budget per message slot for one phase: the max
    total value payload over the phase's messages (>= 1 so empty phases
    still shape a [slots, 1] buffer)."""
    sizes = [message_value_size(m, b_counts)
             for msgs in msg_lists for m in msgs]
    return max(1, max(sizes, default=1))


def local_value_index(mid_part: RowPartition,
                      b_counts: np.ndarray) -> np.ndarray:
    """global B row -> START of its values within its owner's local
    concatenated value array (rows concatenated in ascending-row order) —
    the value-weighted analogue of :meth:`RowPartition.local_index`."""
    start = np.zeros(mid_part.n_rows, dtype=np.int64)
    for r in range(mid_part.n_procs):
        rows = mid_part.rows_of(r)
        if rows.size:
            c = b_counts[rows]
            start[rows] = np.concatenate([[0], np.cumsum(c)[:-1]])
    return start


@dataclasses.dataclass
class SpGemmPlan:
    """A distributed-SpGEMM plan: the SpMV comm graph of A's off-process
    columns + the value-level payload bookkeeping for B's rows.

    ``row_part`` owns A's (and C's) rows; ``mid_part`` owns B's rows (the
    contraction dimension — A's column space).  ``comm`` is the
    underlying :class:`NAPPlan` or :class:`StandardPlan` whose message
    ``idx`` arrays are B-ROW ids; ``b_indptr``/``b_indices`` are the
    B structure snapshot exchanged at plan-build time (value payloads
    flow at run time).
    """

    method: str                       # "nap" | "standard"
    topo: Topology
    row_part: RowPartition
    mid_part: RowPartition
    comm: Union[NAPPlan, StandardPlan]
    b_indptr: np.ndarray
    b_indices: np.ndarray
    shape: Tuple[int, int]            # C = [a_rows, b_cols]

    @functools.cached_property
    def b_counts(self) -> np.ndarray:
        """nnz per B row (cached — compile walks this per rank/phase)."""
        return np.diff(self.b_indptr)

    def value_pads(self) -> Dict[str, int]:
        """Per-phase compile-time value budgets (max values per message)."""
        c = self.b_counts
        if self.method == "standard":
            return {"pair": phase_value_pad(self.comm.sends, c)}
        return {
            "full": phase_value_pad(self.comm.local_full_sends, c),
            "init": phase_value_pad(self.comm.local_init_sends, c),
            "inter": phase_value_pad(self.comm.inter_sends, c),
            "final": phase_value_pad(self.comm.local_final_sends, c),
        }

    def recv_value_map(self, rank: int, phase: str,
                       vpad: int) -> Tuple[np.ndarray, np.ndarray]:
        """Row -> flat value-buffer start map for one recv phase (slot =
        sender local id for intra-node phases / sender rank for the
        standard plan's single phase / sender node id for "inter")."""
        topo = self.topo
        if self.method == "standard":
            assert phase == "pair"
            msgs = self.comm.recvs[rank]
            slots = [m.src for m in msgs]
        else:
            msgs = {"full": self.comm.local_full_recvs,
                    "init": self.comm.local_init_recvs,
                    "final": self.comm.local_final_recvs,
                    "inter": self.comm.inter_recvs}[phase][rank]
            slot_of = topo.node_of if phase == "inter" else topo.local_of
            slots = [slot_of(m.src) for m in msgs]
        return value_slot_map(msgs, slots, self.b_counts, vpad)

    def stats(self, bytes_per_val: int = 8,
              bytes_per_idx: int = 8) -> Dict[str, PhaseStats]:
        """Per-phase message statistics with VALUE-weighted payloads.

        A message carrying rows ``idx`` moves ``sum(b_counts[idx])``
        values plus (one-time, at setup) the same number of column
        indices and one count per row; runtime products move only the
        value bytes, which is what these stats weigh.
        """
        c = self.b_counts

        def of(msg_lists: List[List[Message]]) -> PhaseStats:
            counts = [len(msgs) for msgs in msg_lists]
            sizes = [sum(message_value_size(m, c) for m in msgs) * bytes_per_val
                     for msgs in msg_lists]
            return PhaseStats(
                max_msgs=max(counts, default=0),
                max_bytes=max(sizes, default=0),
                total_msgs=sum(counts), total_bytes=sum(sizes))

        if self.method == "standard":
            topo = self.topo
            inter = [[m for m in msgs if not topo.same_node(m.src, m.dst)]
                     for msgs in self.comm.sends]
            intra = [[m for m in msgs if topo.same_node(m.src, m.dst)]
                     for msgs in self.comm.sends]
            return {"inter": of(inter), "intra": of(intra)}
        intra = [a + b + d for a, b, d in zip(self.comm.local_init_sends,
                                              self.comm.local_full_sends,
                                              self.comm.local_final_sends)]
        return {"inter": of(self.comm.inter_sends), "intra": of(intra)}


def build_spgemm_plan(a: CSR, b: CSR, row_part: RowPartition,
                      mid_part: RowPartition, topo: Topology,
                      method: str = "nap",
                      pairing: str = "aligned") -> SpGemmPlan:
    """Build the SpGEMM communication plan for ``C = A @ B``.

    ``row_part`` owns A's rows (and hence C's); ``mid_part`` owns B's
    rows — A's column dimension (for a Galerkin ``A @ P`` both are the
    fine partition; for ``R @ AP`` the row partition is coarse and the
    mid partition fine).  ``method="nap"`` routes remote B rows through
    the paper's three-step node-aware exchange, ``"standard"`` through
    Algorithm 1's direct point-to-point flow.
    """
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"shapes do not chain: {a.shape} @ {b.shape}")
    if row_part.n_rows != a.shape[0] or mid_part.n_rows != b.shape[0]:
        raise ValueError(
            f"partition mismatch: a is {a.shape}, b is {b.shape}, row_part "
            f"has {row_part.n_rows} rows, mid_part {mid_part.n_rows}")
    if method == "nap":
        comm = build_nap_plan(a.indptr, a.indices, row_part, topo,
                              pairing=pairing, col_part=mid_part)
    elif method == "standard":
        comm = build_standard_plan(a.indptr, a.indices, row_part, topo,
                                   col_part=mid_part)
    else:
        raise ValueError(f"method must be 'nap'|'standard', got {method!r}")
    return SpGemmPlan(method=method, topo=topo, row_part=row_part,
                      mid_part=mid_part, comm=comm,
                      b_indptr=b.indptr.copy(), b_indices=b.indices.copy(),
                      shape=(a.shape[0], b.shape[1]))
