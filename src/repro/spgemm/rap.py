"""Galerkin triple products (RAP) over the distributed SpGEMM.

The AMG setup's dominant communication is the coarse-grid construction
``A_c = R (A P)`` (Bienz et al., arXiv:1904.05838).  This module maps it
onto two node-aware SpGEMMs:

* ``AP = A @ P``  — row partition = fine (A's rows), mid partition =
  fine (P's rows == A's columns);
* ``A_c = R @ AP`` — row partition = coarse (R's rows), mid partition =
  fine (AP's rows == R's columns).

``galerkin_rap`` runs one triple product; ``distributed_rap`` returns a
``rap(r, a, p) -> CSR`` callable pluggable into
:func:`repro.amg.hierarchy.smoothed_aggregation_hierarchy`, so the WHOLE
setup phase — every level's coarse matrix — assembles through the
distributed path.  ``cross_check=True`` keeps the host
:func:`repro.amg.matmul.csr_matmul` as the bit-for-bit float64 oracle on
every product (the simulate backend must match it exactly).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.partition import RowPartition, contiguous_partition
from repro.core.topology import Topology
from repro.spgemm.shardmap import distributed_spgemm
from repro.sparse.csr import CSR


def assert_matches_host(c: CSR, want: CSR, backend: str, label: str,
                        rtol: float = 5e-5) -> None:
    """Assert a distributed product matches the host ``csr_matmul`` result:
    identical structure always; values bit-for-bit on the float64
    ``simulate`` backend, to ``rtol`` (float32/round-off scale) on
    shardmap — callers chaining products level-to-level scale ``rtol``
    with the chain depth."""
    assert c.shape == want.shape, (label, c.shape, want.shape)
    assert np.array_equal(c.indptr, want.indptr) and \
        np.array_equal(c.indices, want.indices), \
        f"{label}: distributed SpGEMM structure diverged from host csr_matmul"
    if backend == "simulate":
        assert np.array_equal(c.data, want.data), \
            f"{label}: float64 simulate SpGEMM must be bit-for-bit equal " \
            f"to host csr_matmul"
    else:
        np.testing.assert_allclose(
            c.data, want.data, rtol=rtol,
            atol=0.1 * rtol * max(1.0, float(np.abs(want.data).max(initial=0.0))),
            err_msg=f"{label}: shardmap SpGEMM values off vs host oracle")


def galerkin_rap(r: CSR, a: CSR, p: CSR, fine_part: RowPartition,
                 coarse_part: RowPartition, topo: Topology, *,
                 method: str = "nap", backend: str = "simulate",
                 mesh=None, dtype=None, cross_check: bool = False) -> CSR:
    """Distributed ``A_c = R @ A @ P`` on (fine, coarse) partitions.

    ``backend="simulate"`` (default) is the exact float64 path — suitable
    for hierarchy construction, bit-for-bit equal to the host product;
    ``"shardmap"`` runs both products through the SPMD program.
    """
    if a.shape != (fine_part.n_rows, fine_part.n_rows):
        raise ValueError(f"A {a.shape} does not match the fine partition "
                         f"({fine_part.n_rows} rows)")
    if p.shape != (fine_part.n_rows, coarse_part.n_rows) or \
            r.shape != (coarse_part.n_rows, fine_part.n_rows):
        raise ValueError(f"P {p.shape} / R {r.shape} do not match "
                         f"fine={fine_part.n_rows}, "
                         f"coarse={coarse_part.n_rows}")
    ap = distributed_spgemm(a, p, fine_part, fine_part, topo,
                            method=method, backend=backend, mesh=mesh,
                            dtype=dtype)
    a_c = distributed_spgemm(r, ap, coarse_part, fine_part, topo,
                             method=method, backend=backend, mesh=mesh,
                             dtype=dtype)
    if cross_check:
        from repro.amg.matmul import csr_matmul
        assert_matches_host(a_c, csr_matmul(r, csr_matmul(a, p)),
                            backend, "RAP")
    return a_c


def distributed_rap(topo: Topology, *, method: str = "nap",
                    backend: str = "simulate", mesh=None, dtype=None,
                    cross_check: bool = False,
                    make_part: Optional[Callable[[int], RowPartition]] = None
                    ) -> Callable[[CSR, CSR, CSR], CSR]:
    """A ``rap(r, a, p) -> CSR`` callable for the hierarchy builder.

    Each invocation derives the fine/coarse partitions from the operand
    shapes (``make_part(n)`` defaults to ``contiguous_partition(n,
    topo.n_procs)`` — coarse levels smaller than the machine simply get
    empty ranks) and runs the two-product Galerkin chain through the
    distributed SpGEMM.  Plug into ``smoothed_aggregation_hierarchy(...,
    rap=distributed_rap(topo))`` to make the whole AMG setup node-aware.
    """
    mk = make_part or (lambda n: contiguous_partition(n, topo.n_procs))

    def rap(r: CSR, a: CSR, p: CSR) -> CSR:
        fine = mk(a.shape[0])
        coarse = mk(p.shape[1])
        return galerkin_rap(r, a, p, fine, coarse, topo, method=method,
                            backend=backend, mesh=mesh, dtype=dtype,
                            cross_check=cross_check)

    return rap
