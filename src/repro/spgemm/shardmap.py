"""SPMD (shard_map) executor for the node-aware distributed SpGEMM.

The on-device program mirrors the NAPSpMV three-step exactly — intra-node
all_to_all (fully-local + init), ONE aggregated inter-node all_to_all,
intra-node final scatter — with every buffer generalised from vector
slots to **value-level row blocks**: a message slot of the SpMV carried
one x value per index; here it carries the concatenated CSR values of
the B rows it names, padded to the compile-time value budget of its
phase (:meth:`repro.spgemm.plan.SpGemmPlan.value_pads`).  Row structure
(indices + counts) never moves at run time: it is compiled into static
gather maps host-side at plan build, exactly where the SpMV plans bake
their slot maps.

Local compute is the vectorised row-expansion kernel of
:func:`repro.amg.matmul.csr_matmul` ported to jnp: every local A nonzero
``a_ik`` multiplies B row k gathered from the packed value domain
``[b_loc | full_recv | inter_recv | final_recv]`` (positions precomputed
per expanded product), and duplicates merge with one ``segment_sum``
into the precomputed C nnz slots.  C's structure (the merged sparsity of
every rank's rows) is compiled host-side; the device program computes
values only, so re-running with new B values (same structure) costs one
pack -> SPMD run -> unpack.

``dtype`` selects the payload precision: float32 (the repo's device
default) or float64 when jax's x64 mode is enabled — the float64 program
matches the host ``csr_matmul`` to round-off (~1 ulp: XLA's scatter-add
associates sums differently than the host ``reduceat``; the *simulate*
backend of :mod:`repro.spgemm.simulate` is the bit-for-bit oracle).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.integrity import (IntegrityError, NAP_MESSAGE_PHASES,
                                  build_fault_spec, message_phases,
                                  phase_index, verify_wire)
from repro.core.partition import RowPartition
from repro.core.topology import Topology
from repro.spgemm.plan import (SpGemmPlan, build_spgemm_plan,
                               expand_positions, local_value_index,
                               lookup_row_starts)
from repro.spgemm.simulate import simulate_spgemm
from repro.sparse.csr import CSR

# number of shard_map SpGEMM program applications this process has run —
# the multidev sweep asserts hierarchy assembly actually went through the
# device program (not a host fallback)
_RUN_COUNTER = {"runs": 0}


def shardmap_spgemm_runs() -> int:
    return _RUN_COUNTER["runs"]


_MESH_CACHE: Dict[Tuple[int, int], object] = {}


def _default_mesh(topo: Topology):
    """One ("node", "proc") mesh per topology shape — a stable mesh
    identity keeps the per-compiled-plan program memo effective."""
    key = (topo.n_nodes, topo.ppn)
    if key not in _MESH_CACHE:
        from repro.compat import make_mesh
        _MESH_CACHE[key] = make_mesh(key, ("node", "proc"))
    return _MESH_CACHE[key]


def _spgemm_namespace():
    """Registry-backed device memo (repro.mesh.buffers), like SpMV plans."""
    from repro.mesh.buffers import default_registry
    return default_registry().namespace("spgemm-plan")


@dataclasses.dataclass
class CompiledSpGemm:
    """Static arrays for the shard_map SpGEMM, stacked over ranks.

    ``arrays`` holds the per-phase value gather maps + the expansion
    triple (positions into the packed value domain, output C slots, A
    values); ``c_rows``/``c_cols``/``c_nnz`` the host-side C structure
    used to assemble the global CSR from per-rank value shards.
    """

    topo: Topology
    row_part: RowPartition
    mid_part: RowPartition
    shape: Tuple[int, int]
    method: str
    b_nnz_pad: int
    vpads: Dict[str, int]
    exp_pad: int
    c_nnz_pad: int
    arrays: Dict[str, np.ndarray]
    c_rows: List[np.ndarray]          # per rank: global C row ids (merged)
    c_cols: List[np.ndarray]          # per rank: C col ids (merged, row-major)
    c_nnz: List[int]
    plan: Optional[SpGemmPlan] = None
    _dev_cache: Dict[str, object] = dataclasses.field(
        default_factory=_spgemm_namespace, repr=False, compare=False)
    # jitted program memo per (mesh id, payload dtype): repeated
    # applications (AMG setup sweeps, benchmarks) re-use one trace
    _run_cache: Dict[tuple, object] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def device_arrays(self, dtype) -> Dict[str, object]:
        import jax.numpy as jnp
        from repro.core.spmv_jax import _memo_device_arrays

        arrs = dict(self.arrays)
        # exp_a is staged per requested payload dtype (cache key per dtype)
        key = f"exp_a_{np.dtype(dtype).name}"
        arrs[key] = self.arrays["exp_a"].astype(dtype)
        del arrs["exp_a"]
        out = _memo_device_arrays(self.topo, arrs, self._dev_cache)
        out["exp_a"] = out.pop(key)
        return out


_SPGEMM_CACHE: Dict[tuple, CompiledSpGemm] = {}
_SPGEMM_CACHE_MAX = 8


def clear_spgemm_cache() -> None:
    _SPGEMM_CACHE.clear()


def _spgemm_cache_key(a: CSR, b: CSR, row_part: RowPartition,
                      mid_part: RowPartition, topo: Topology,
                      method: str) -> tuple:
    h = hashlib.sha1()
    # A's values are baked into the expansion arrays; B's values are a
    # runtime input, so only B's STRUCTURE keys the compiled program.
    for arr in (a.indptr, a.indices, a.data, b.indptr, b.indices,
                row_part.owner, mid_part.owner):
        h.update(np.ascontiguousarray(arr).tobytes())
    return (method, h.hexdigest(), a.shape, b.shape, topo.n_nodes, topo.ppn)


def compile_spgemm(a: CSR, b: CSR, row_part: RowPartition,
                   mid_part: RowPartition, topo: Topology,
                   method: str = "nap", plan: Optional[SpGemmPlan] = None,
                   cache: bool = True) -> CompiledSpGemm:
    """Compile the SpGEMM plan into static shard_map arrays.

    Builds (or accepts) the :class:`SpGemmPlan`, resolves every B row a
    rank consumes to its position in the packed value domain, expands
    the local products and merges C's structure — all bulk numpy, cached
    like :func:`repro.core.spmv_jax.compile_nap`.
    """
    key = None
    if plan is None and cache:
        key = _spgemm_cache_key(a, b, row_part, mid_part, topo, method)
        hit = _SPGEMM_CACHE.pop(key, None)
        if hit is not None:
            _SPGEMM_CACHE[key] = hit
            return hit
    if plan is None:
        plan = build_spgemm_plan(a, b, row_part, mid_part, topo,
                                 method=method)
    assert plan.method == method
    comm = plan.comm
    n_procs, ppn, n_nodes = topo.n_procs, topo.ppn, topo.n_nodes
    b_counts = plan.b_counts
    lvi = local_value_index(mid_part, b_counts)
    owner = mid_part.owner
    b_nnz_pad = max(1, int(mid_part_value_counts(mid_part, b_counts).max()))
    vpads = plan.value_pads()

    def send_map(msgs, n_slots: int, vpad: int, slot_of, base_of) -> np.ndarray:
        out = np.zeros((n_slots, vpad), dtype=np.int32)
        for m in msgs:
            pos = expand_positions(base_of(m.idx), b_counts[m.idx])
            out[slot_of(m), : pos.size] = pos
        return out

    arrays: Dict[str, np.ndarray] = {}
    per_rank: Dict[str, List[np.ndarray]] = {k: [] for k in (
        "full_send_v", "init_send_v", "inter_gather_v", "final_send_v",
        "send_v", "exp_pos", "exp_out", "exp_a")}
    c_rows: List[np.ndarray] = []
    c_cols: List[np.ndarray] = []
    c_nnz: List[int] = []

    if method == "nap":
        off_full = b_nnz_pad
        off_inter = off_full + ppn * vpads["full"]
        off_final = off_inter + n_nodes * vpads["inter"]
        domain_len = off_final + ppn * vpads["final"]
    else:
        off_recv = b_nnz_pad
        domain_len = off_recv + n_procs * vpads["pair"]

    for r in range(n_procs):
        loc_base = lambda idx: lvi[idx]

        if method == "nap":
            per_rank["full_send_v"].append(send_map(
                comm.local_full_sends[r], ppn, vpads["full"],
                lambda m: topo.local_of(m.dst), loc_base))
            per_rank["init_send_v"].append(send_map(
                comm.local_init_sends[r], ppn, vpads["init"],
                lambda m: topo.local_of(m.dst), loc_base))

            init_map = plan.recv_value_map(r, "init", vpads["init"])

            def inter_base(idx: np.ndarray) -> np.ndarray:
                own = owner[idx] == r
                base = np.empty(idx.size, dtype=np.int64)
                base[own] = lvi[idx[own]]
                if not own.all():
                    base[~own] = b_nnz_pad + lookup_row_starts(
                        init_map, idx[~own])
                return base

            per_rank["inter_gather_v"].append(send_map(
                comm.inter_sends[r], n_nodes, vpads["inter"],
                lambda m: topo.node_of(m.dst), inter_base))

            inter_map = plan.recv_value_map(r, "inter", vpads["inter"])
            per_rank["final_send_v"].append(send_map(
                comm.local_final_sends[r], ppn, vpads["final"],
                lambda m: topo.local_of(m.dst),
                lambda idx: lookup_row_starts(inter_map, idx)))

            full_map = plan.recv_value_map(r, "full", vpads["full"])
            final_map = plan.recv_value_map(r, "final", vpads["final"])
            # combined off-node row -> domain start (inter buffer when this
            # rank is the row's home, final buffer otherwise; disjoint)
            comb_rows = np.concatenate([inter_map[0], final_map[0]])
            comb_starts = np.concatenate([off_inter + inter_map[1],
                                          off_final + final_map[1]])
            order = np.argsort(comb_rows, kind="stable")
            comb = (comb_rows[order], comb_starts[order])
            assert comb[0].size < 2 or (np.diff(comb[0]) > 0).all(), \
                "off-node B row delivered through two phases"

            def domain_base(k: np.ndarray) -> np.ndarray:
                own = owner[k] == r
                on_node = (~own) & (topo.node_of_array(owner[k])
                                    == topo.node_of(r))
                off = ~(own | on_node)
                base = np.empty(k.size, dtype=np.int64)
                base[own] = lvi[k[own]]
                if on_node.any():
                    base[on_node] = off_full + lookup_row_starts(
                        full_map, k[on_node])
                if off.any():
                    base[off] = lookup_row_starts(comb, k[off])
                return base
        else:
            per_rank["send_v"].append(send_map(
                comm.sends[r], n_procs, vpads["pair"],
                lambda m: m.dst, loc_base))
            pair_map = plan.recv_value_map(r, "pair", vpads["pair"])

            def domain_base(k: np.ndarray) -> np.ndarray:
                own = owner[k] == r
                base = np.empty(k.size, dtype=np.int64)
                base[own] = lvi[k[own]]
                if not own.all():
                    base[~own] = off_recv + lookup_row_starts(
                        pair_map, k[~own])
                return base

        # -- row expansion + C structure merge (per rank, bulk numpy) --------
        g_rows = row_part.rows_of(r)
        local = a.select_rows(g_rows)
        ai, ak, av = local.to_coo()
        counts = b_counts[ak] if ak.size else np.empty(0, dtype=np.int64)
        pos = expand_positions(domain_base(ak) if ak.size
                                else np.empty(0, dtype=np.int64), counts)
        b_take = expand_positions(plan.b_indptr[ak] if ak.size
                                   else np.empty(0, dtype=np.int64), counts)
        cols_exp = plan.b_indices[b_take]
        rows_exp = np.repeat(ai, counts)
        a_exp = np.repeat(av, counts)
        key_exp = rows_exp * np.int64(plan.shape[1]) + cols_exp
        uniq, exp_out = np.unique(key_exp, return_inverse=True)
        per_rank["exp_pos"].append(pos.astype(np.int32))
        per_rank["exp_out"].append(exp_out.astype(np.int32))
        per_rank["exp_a"].append(a_exp)
        c_rows.append(g_rows[(uniq // plan.shape[1]).astype(np.int64)])
        c_cols.append((uniq % plan.shape[1]).astype(np.int64))
        c_nnz.append(int(uniq.size))

    assert domain_len < np.iinfo(np.int32).max

    exp_pad = max(1, max(p.size for p in per_rank["exp_pos"]))
    c_nnz_pad = max(1, max(c_nnz))

    def stack(name: str, pads: Tuple[int, ...], dtype=np.int32,
              fill=0) -> None:
        out = np.full((n_procs,) + pads, fill, dtype=dtype)
        for r, arr in enumerate(per_rank[name]):
            if arr.ndim == 1:
                out[r, : arr.size] = arr
            else:
                out[r] = arr
        arrays[name] = out

    if method == "nap":
        stack("full_send_v", (ppn, vpads["full"]))
        stack("init_send_v", (ppn, vpads["init"]))
        stack("inter_gather_v", (n_nodes, vpads["inter"]))
        stack("final_send_v", (ppn, vpads["final"]))
    else:
        stack("send_v", (n_procs, vpads["pair"]))
    stack("exp_pos", (exp_pad,))
    stack("exp_out", (exp_pad,))
    stack("exp_a", (exp_pad,), dtype=np.float64, fill=0.0)

    compiled = CompiledSpGemm(
        topo=topo, row_part=row_part, mid_part=mid_part, shape=plan.shape,
        method=method, b_nnz_pad=b_nnz_pad, vpads=vpads, exp_pad=exp_pad,
        c_nnz_pad=c_nnz_pad, arrays=arrays, c_rows=c_rows, c_cols=c_cols,
        c_nnz=c_nnz, plan=plan)
    if key is not None:
        while len(_SPGEMM_CACHE) >= _SPGEMM_CACHE_MAX:
            _SPGEMM_CACHE.pop(next(iter(_SPGEMM_CACHE)))
        _SPGEMM_CACHE[key] = compiled
    return compiled


def mid_part_value_counts(mid_part: RowPartition,
                          b_counts: np.ndarray) -> np.ndarray:
    """Total B values owned per rank (the b_loc shard lengths)."""
    out = np.zeros(mid_part.n_procs, dtype=np.int64)
    for r in range(mid_part.n_procs):
        rows = mid_part.rows_of(r)
        out[r] = int(b_counts[rows].sum()) if rows.size else 0
    return out


def pack_b_values(b: CSR, compiled: CompiledSpGemm, dtype) -> np.ndarray:
    """B values -> [n_nodes, ppn, b_nnz_pad] shards (rows concatenated in
    ascending-row order per owner, matching :func:`local_value_index`)."""
    topo, part = compiled.topo, compiled.mid_part
    out = np.zeros((topo.n_procs, compiled.b_nnz_pad), dtype=dtype)
    counts = np.diff(b.indptr)
    for r in range(topo.n_procs):
        rows = part.rows_of(r)
        if rows.size:
            take = expand_positions(b.indptr[rows], counts[rows])
            out[r, : take.size] = b.data[take]
    return out.reshape(topo.n_nodes, topo.ppn, compiled.b_nnz_pad)


def unpack_c_values(c_shards: np.ndarray, compiled: CompiledSpGemm) -> CSR:
    """Per-rank C value shards -> the global C CSR (host structure +
    device values).  Per-rank slots beyond ``c_nnz[r]`` are padding."""
    topo = compiled.topo
    w = np.asarray(c_shards).reshape(topo.n_procs, -1)
    rows = np.concatenate(compiled.c_rows) if compiled.c_rows else \
        np.empty(0, dtype=np.int64)
    cols = np.concatenate(compiled.c_cols) if compiled.c_cols else \
        np.empty(0, dtype=np.int64)
    vals = np.concatenate([w[r, : compiled.c_nnz[r]].astype(np.float64)
                           for r in range(topo.n_procs)]) if rows.size else \
        np.empty(0, dtype=np.float64)
    # per-rank structure is merged and row-major; the global from_coo is a
    # pure re-sort across ranks (each C row lives on exactly one rank)
    return CSR.from_coo(rows, cols, vals, compiled.shape,
                        sum_duplicates=False)


def spgemm_shardmap(compiled: CompiledSpGemm, mesh, dtype=None,
                    integrity: bool = False):
    """Build the jitted shard_map SpGEMM: f(b_shards) -> c_value_shards.

    ``b_shards`` is [n_nodes, ppn, b_nnz_pad] (``pack_b_values``); the
    output [n_nodes, ppn, c_nnz_pad] per-rank C values in the compiled
    structure's order.  ``dtype`` pins the payload precision (float32
    default; float64 needs jax x64 mode and matches the host product to
    round-off — the simulate backend is the bit-for-bit oracle).

    ``integrity=True`` builds the INSTRUMENTED program: every value-block
    payload is checksummed by the sender before the scripted fault
    boundary, the per-call fault-spec argument (the SpMV operators'
    :func:`repro.core.integrity.build_fault_spec` array) is applied as a
    pure transform at the pack boundary, and the receiver recomputes
    after the collective — ``run(b_shards, fault_spec)`` then returns
    ``(c_shards, chk)`` with ``chk`` the
    [n_nodes, ppn, n_phases, 2, max_slots] aux output of
    :func:`repro.core.integrity.verify_wire`.  With ``integrity=False``
    the emitted program is the bare one, bit-for-bit.
    """
    import jax
    import jax.numpy as jnp
    from jax.ops import segment_sum
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.spmv_jax import _apply_fault, _msg_checksums, _stack_chk

    if dtype is None:
        dtype = jnp.float32
    run_key = (id(mesh), np.dtype(dtype).name, bool(integrity))
    hit = compiled._run_cache.get(run_key)
    if hit is not None:
        return hit
    topo = compiled.topo
    nn, ppn = topo.n_nodes, topo.ppn
    c_nnz_pad, vpads = compiled.c_nnz_pad, compiled.vpads
    ph = phase_index(compiled.method)
    max_slots = max(ppn, nn) if compiled.method == "nap" else topo.n_procs

    def make_exchange(fault_spec, chks):
        # Sender checksums the CLEAN payload, the scripted fault (if
        # armed for this device+phase) corrupts it at the pack boundary,
        # payload and checksum words travel through the same collective,
        # the receiver recomputes.  Uninstrumented this is literally the
        # bare all_to_all.
        def exchange(buf, phase, axis):
            if not integrity:
                return jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            sent = _msg_checksums(buf)
            buf = _apply_fault(buf, fault_spec[ph[phase]])
            recv = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
            expect = jax.lax.all_to_all(sent[:, None], axis, 0, 0,
                                        tiled=True)[:, 0]
            chks[phase] = (expect, _msg_checksums(recv))
            return recv
        return exchange

    if compiled.method == "nap":
        names = ["full_send_v", "init_send_v", "inter_gather_v",
                 "final_send_v", "exp_pos", "exp_out", "exp_a"]

        def per_device(b_loc, *args):
            squeeze = lambda x: x.reshape(x.shape[2:])
            fault_spec = None
            if integrity:
                fault_spec = squeeze(args[0])               # [n_phases, 4]
                args = args[1:]
            (b_loc, full_send_v, init_send_v, inter_gather_v, final_send_v,
             exp_pos, exp_out, exp_a) = map(squeeze, (b_loc,) + args)
            chks = {}
            exchange = make_exchange(fault_spec, chks)
            # Phases A+B: intra-node row-block exchanges over "proc".
            full_recv = exchange(b_loc[full_send_v], "full", "proc")
            init_recv = exchange(b_loc[init_send_v], "init", "proc")
            # Phase C: ONE aggregated inter-node all_to_all over "node".
            staged = jnp.concatenate([b_loc, init_recv.reshape(-1)])
            inter_recv = exchange(staged[inter_gather_v], "inter", "node")
            inter_flat = inter_recv.reshape(-1)
            # Phase D: intra-node scatter of the aggregated rows.
            final_recv = exchange(inter_flat[final_send_v], "final", "proc")
            domain = jnp.concatenate([b_loc, full_recv.reshape(-1),
                                      inter_flat, final_recv.reshape(-1)])
            # local compute: csr_matmul's row expansion + duplicate merge
            c = segment_sum(exp_a * domain[exp_pos], exp_out,
                            num_segments=c_nnz_pad)
            if not integrity:
                return c.reshape(1, 1, c_nnz_pad)
            chk = _stack_chk([chks[p] for p in NAP_MESSAGE_PHASES],
                             max_slots)
            return (c.reshape(1, 1, c_nnz_pad),
                    chk.reshape((1, 1) + chk.shape))
    else:
        names = ["send_v", "exp_pos", "exp_out", "exp_a"]

        def per_device(b_loc, *args):
            squeeze = lambda x: x.reshape(x.shape[2:])
            fault_spec = None
            if integrity:
                fault_spec = squeeze(args[0])
                args = args[1:]
            b_loc, send_v, exp_pos, exp_out, exp_a = map(
                squeeze, (b_loc,) + args)
            chks = {}
            exchange = make_exchange(fault_spec, chks)
            recv = exchange(b_loc[send_v], "pair", ("node", "proc"))
            domain = jnp.concatenate([b_loc, recv.reshape(-1)])
            c = segment_sum(exp_a * domain[exp_pos], exp_out,
                            num_segments=c_nnz_pad)
            if not integrity:
                return c.reshape(1, 1, c_nnz_pad)
            chk = _stack_chk([chks["pair"]], max_slots)
            return (c.reshape(1, 1, c_nnz_pad),
                    chk.reshape((1, 1) + chk.shape))

    dev = compiled.device_arrays(dtype)
    spec = P("node", "proc")
    n_in = 1 + len(names) + (1 if integrity else 0)
    smapped = shard_map(per_device, mesh=mesh,
                        in_specs=(spec,) * n_in,
                        out_specs=(spec, spec) if integrity else spec,
                        check_vma=False)
    if integrity:
        jitted = jax.jit(lambda b_shards, fault_spec: smapped(
            b_shards, fault_spec, *[dev[k] for k in names]))

        def run(b_shards, fault_spec):
            import jax.numpy as jnp
            _RUN_COUNTER["runs"] += 1
            return jitted(jnp.asarray(b_shards, dtype),
                          jnp.asarray(np.asarray(fault_spec), jnp.int32))
    else:
        jitted = jax.jit(lambda b_shards: smapped(
            b_shards, *[dev[k] for k in names]))

        def run(b_shards):
            import jax.numpy as jnp
            _RUN_COUNTER["runs"] += 1
            return jitted(jnp.asarray(b_shards, dtype))

    run.method = compiled.method
    run.integrity = bool(integrity)
    compiled._run_cache[run_key] = run
    return run


def distributed_spgemm(a: CSR, b: CSR, row_part: RowPartition,
                       mid_part: RowPartition, topo: Topology, *,
                       method: str = "nap", backend: str = "shardmap",
                       mesh=None, dtype=None, cache: bool = True,
                       integrity: str = "off", faults=(),
                       report: Optional[dict] = None) -> CSR:
    """One-call distributed ``C = A @ B``.

    ``backend="simulate"`` runs the exact float64 message-passing oracle
    (bit-for-bit equal to the host :func:`repro.amg.matmul.csr_matmul`);
    ``"shardmap"`` compiles and runs the SPMD program (float32 payloads
    by default; ``dtype=jnp.float64`` under jax x64 mode matches the
    host product to round-off).

    ``integrity="detect"`` runs the checksum-instrumented program and
    raises :class:`repro.core.integrity.IntegrityError` with
    phase+message attribution when any value-exchange payload arrives
    different from what the sender packed; ``"recover"`` retries the
    whole product once with the fault boundary cleared (the scripted
    faults in ``faults`` — :class:`repro.core.integrity.MessageFault`
    on this method's exchange phases, forward direction — fire on the
    first run only, so a recovered product is bit-identical to the
    fault-free run).  Pass a dict as ``report`` to receive the check
    counters.  Integrity is shardmap-only: the simulate backend IS the
    bit-exact oracle the checks are calibrated against.
    """
    if integrity not in ("off", "detect", "recover"):
        raise ValueError(f"integrity must be 'off'|'detect'|'recover', "
                         f"got {integrity!r}")
    if faults and integrity == "off":
        raise ValueError("scripted message faults need "
                         "integrity='detect'|'recover'")
    if integrity != "off" and backend != "shardmap":
        raise ValueError("integrity-checked SpGEMM is shardmap-only (the "
                         "simulate backend is the bit-exact oracle the "
                         "checks are calibrated against)")
    if backend == "simulate":
        plan = build_spgemm_plan(a, b, row_part, mid_part, topo,
                                 method=method)
        return simulate_spgemm(a, b, plan)
    if backend != "shardmap":
        raise ValueError(f"backend must be 'shardmap'|'simulate', "
                         f"got {backend!r}")
    compiled = compile_spgemm(a, b, row_part, mid_part, topo, method=method,
                              cache=cache)
    if mesh is None:
        mesh = _default_mesh(topo)
    np_dtype = np.dtype(np.float32 if dtype is None else dtype)
    b_shards = pack_b_values(b, compiled, np_dtype)
    if integrity == "off":
        run = spgemm_shardmap(compiled, mesh, dtype=dtype)
        return unpack_c_values(np.asarray(run(b_shards)), compiled)

    for f in faults:
        if f.direction not in ("any", "forward"):
            raise ValueError("SpGEMM message faults are forward-only "
                             "(the product has no transpose exchange)")
        if f.phase == "compute":
            raise ValueError("SpGEMM integrity covers the value exchanges; "
                             "compute-side faults belong to the SpMV "
                             "operators' ABFT check")
    run = spgemm_shardmap(compiled, mesh, dtype=dtype, integrity=True)
    spec = build_fault_spec(topo, faults, method)
    phases = message_phases(method)
    counters = {"wire_checks": topo.n_procs * len(phases),
                "wire_mismatches": 0, "faults_injected": len(list(faults)),
                "retries": 0, "recovered": 0}
    c_shards, chk = run(b_shards, spec)
    mism = verify_wire(np.asarray(chk), phases, topo.ppn, "forward")
    if mism:
        counters["wire_mismatches"] = len(mism)
        if integrity == "detect":
            if report is not None:
                report.update(counters)
            raise IntegrityError(
                f"{len(mism)} integrity mismatch(es) in distributed "
                f"SpGEMM: " + "; ".join(str(m) for m in mism), mism)
        counters["retries"] = 1
        c_shards, chk = run(b_shards, np.zeros_like(spec))
        again = verify_wire(np.asarray(chk), phases, topo.ppn, "forward")
        if again:
            if report is not None:
                report.update(counters)
            raise IntegrityError(
                "integrity mismatch persisted through the clean SpGEMM "
                "retry: " + "; ".join(str(m) for m in again), again)
        counters["recovered"] = 1
    if report is not None:
        report.update(counters)
    return unpack_c_values(np.asarray(c_shards), compiled)
