"""Exact message-passing simulators for the distributed SpGEMM (float64).

These mirror the MPI flow of :func:`repro.core.spmv.simulate_nap_spmv` /
``simulate_standard_spmv`` with the payload generalised from one scalar
per vector index to the variable-length value block of one B row per
index: each rank touches only B values it owns (``mid_part``) or that
arrived in a plan message, routes them through the plan's phases (for
the node-aware plan: fully-local exchange, init redistribution, ONE
aggregated inter-node exchange, final scatter), and multiplies its local
A rows against the gathered rows with the same vectorised row-expansion
+ stable duplicate merge as :func:`repro.amg.matmul.csr_matmul` — so the
assembled global C is **bit-for-bit equal** to the host product in
float64 (identical product enumeration order, identical ``reduceat``
summation order).  This is the correctness oracle for the shard_map
SpGEMM program and the float64 path of ``materialize=True`` AMG setups.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.comm_graph import Message
from repro.spgemm.plan import SpGemmPlan, expand_positions
from repro.sparse.csr import CSR


class _RowMailBox:
    """Delivers one message's concatenated B-row values, keyed (src, dst)
    like :class:`repro.core.spmv._MailBox` (one message per ordered pair
    per phase by plan construction)."""

    def __init__(self, b_counts: np.ndarray) -> None:
        self.b_counts = b_counts
        self.store: Dict[tuple, np.ndarray] = {}

    def post(self, msg: Message, rows: Dict[int, np.ndarray]) -> None:
        vals = [rows[int(k)] for k in msg.idx]  # KeyError = never received
        payload = (np.concatenate(vals) if vals
                   else np.empty(0, dtype=np.float64))
        assert payload.size == int(self.b_counts[msg.idx].sum())
        key = (msg.src, msg.dst)
        assert key not in self.store, f"duplicate message for {key}"
        self.store[key] = payload

    def fetch(self, msg: Message) -> Dict[int, np.ndarray]:
        payload = self.store[(msg.src, msg.dst)]
        bounds = np.cumsum(self.b_counts[msg.idx])[:-1]
        return {int(k): v for k, v in zip(msg.idx, np.split(payload, bounds))}


def _owned_rows(b: CSR, plan: SpGemmPlan, rank: int) -> Dict[int, np.ndarray]:
    return {int(k): b.data[b.indptr[k]: b.indptr[k + 1]].astype(np.float64)
            for k in plan.mid_part.rows_of(rank)}


def _rank_product(a: CSR, plan: SpGemmPlan, rank: int,
                  rows_avail: Dict[int, np.ndarray]):
    """(global C rows, cols, merged vals) of rank's C rows, computed from
    its local A rows and the available B rows only.

    Product enumeration order is A row-major (rows ascending, then A's
    stored column order, then B-row order) and duplicates merge through
    ``CSR.from_coo``'s stable sort + ``reduceat`` — the exact order
    :func:`repro.amg.matmul.csr_matmul` uses, hence bit-for-bit parity.
    """
    g_rows = plan.row_part.rows_of(rank)
    local = a.select_rows(g_rows)
    ai, ak, av = local.to_coo()
    if ai.size == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z.copy(), np.empty(0, dtype=np.float64)
    b_counts, b_indptr, b_indices = (plan.b_counts, plan.b_indptr,
                                     plan.b_indices)
    # compact per-rank B store over the rows this rank's A references
    needed = np.unique(ak)
    missing = [int(k) for k in needed if int(k) not in rows_avail]
    assert not missing, f"rank {rank} accessed B rows it never " \
                        f"received: {missing[:8]}"
    store_vals = (np.concatenate([rows_avail[int(k)] for k in needed])
                  if needed.size else np.empty(0, dtype=np.float64))
    store_cols = (np.concatenate([b_indices[b_indptr[k]: b_indptr[k + 1]]
                                  for k in needed])
                  if needed.size else np.empty(0, dtype=np.int64))
    nc = b_counts[needed]
    store_start = np.concatenate([[0], np.cumsum(nc)[:-1]]).astype(np.int64)
    # vectorised row expansion (the csr_matmul kernel over the store)
    k_pos = np.searchsorted(needed, ak)
    counts = nc[k_pos]
    take = expand_positions(store_start[k_pos], counts)
    if take.size == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z.copy(), np.empty(0, dtype=np.float64)
    rows = np.repeat(ai, counts)
    cols = store_cols[take]
    vals = np.repeat(av, counts) * store_vals[take]
    merged = CSR.from_coo(rows, cols, vals, (g_rows.size, plan.shape[1]))
    mr, mc, mv = merged.to_coo()
    return g_rows[mr], mc, mv


def _assemble(parts: List[tuple], shape) -> CSR:
    rows = np.concatenate([p[0] for p in parts]) if parts else np.empty(0, np.int64)
    cols = np.concatenate([p[1] for p in parts]) if parts else np.empty(0, np.int64)
    vals = np.concatenate([p[2] for p in parts]) if parts else np.empty(0)
    # per-rank results are already duplicate-merged and each C row is
    # computed by exactly one rank: a pure re-sort, never a re-sum
    return CSR.from_coo(rows, cols, vals, shape, sum_duplicates=False)


def simulate_standard_spgemm(a: CSR, b: CSR, plan: SpGemmPlan) -> CSR:
    """Algorithm 1's flat exchange carrying B-row value blocks."""
    assert plan.method == "standard", plan.method
    topo, comm = plan.topo, plan.comm
    box = _RowMailBox(plan.b_counts)
    owned = [_owned_rows(b, plan, r) for r in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in comm.sends[r]:
            box.post(msg, owned[r])
    parts = []
    for r in range(topo.n_procs):
        avail = dict(owned[r])
        for msg in comm.recvs[r]:
            avail.update(box.fetch(msg))
        parts.append(_rank_product(a, plan, r, avail))
    return _assemble(parts, plan.shape)


def simulate_nap_spgemm(a: CSR, b: CSR, plan: SpGemmPlan) -> CSR:
    """Algorithms 2+3 generalised to row-block payloads: fully-local and
    init exchanges first, then the single aggregated inter-node exchange,
    then the final on-node scatter — the only network injection is the
    inter phase, exactly as in the node-aware SpMV."""
    assert plan.method == "nap", plan.method
    topo, comm = plan.topo, plan.comm
    owned = [_owned_rows(b, plan, r) for r in range(topo.n_procs)]

    # -- phase A: fully-local exchange (on_node -> on_node) ------------------
    box_full = _RowMailBox(plan.b_counts)
    for r in range(topo.n_procs):
        for msg in comm.local_full_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_full.post(msg, owned[r])

    # -- phase B: init redistribution (owner -> staging rank, on node) -------
    box_init = _RowMailBox(plan.b_counts)
    for r in range(topo.n_procs):
        for msg in comm.local_init_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_init.post(msg, owned[r])
    staged = [dict(owned[r]) for r in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in comm.local_init_recvs[r]:
            staged[r].update(box_init.fetch(msg))

    # -- phase C: the ONE aggregated inter-node exchange ---------------------
    box_inter = _RowMailBox(plan.b_counts)
    for r in range(topo.n_procs):
        for msg in comm.inter_sends[r]:
            assert not topo.same_node(msg.src, msg.dst)
            box_inter.post(msg, staged[r])
    arrived: List[Dict[int, np.ndarray]] = [dict() for _ in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in comm.inter_recvs[r]:
            arrived[r].update(box_inter.fetch(msg))

    # -- phase D: final on-node scatter (home rank -> consumers) -------------
    box_final = _RowMailBox(plan.b_counts)
    for r in range(topo.n_procs):
        for msg in comm.local_final_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_final.post(msg, arrived[r])
    for r in range(topo.n_procs):
        for msg in comm.local_final_recvs[r]:
            arrived[r].update(box_final.fetch(msg))

    # -- local products: owned + on-node (full) + off-node (arrived) rows ----
    parts = []
    for r in range(topo.n_procs):
        avail = dict(owned[r])
        for msg in comm.local_full_recvs[r]:
            avail.update(box_full.fetch(msg))
        avail.update(arrived[r])
        parts.append(_rank_product(a, plan, r, avail))
    return _assemble(parts, plan.shape)


def simulate_spgemm(a: CSR, b: CSR, plan: SpGemmPlan) -> CSR:
    """Dispatch on the plan's method."""
    if plan.method == "nap":
        return simulate_nap_spgemm(a, b, plan)
    return simulate_standard_spgemm(a, b, plan)
