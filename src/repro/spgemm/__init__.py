"""Node-aware distributed SpGEMM: ``C = A @ B`` over independent row
partitions, routed through the paper's three-step exchange with
row-block payloads.  See ``src/repro/spgemm/README.md``."""
from repro.spgemm.plan import SpGemmPlan, build_spgemm_plan
from repro.spgemm.rap import (assert_matches_host, distributed_rap,
                              galerkin_rap)
from repro.spgemm.shardmap import (CompiledSpGemm, clear_spgemm_cache,
                                   compile_spgemm, distributed_spgemm,
                                   pack_b_values, shardmap_spgemm_runs,
                                   spgemm_shardmap, unpack_c_values)
from repro.spgemm.simulate import (simulate_nap_spgemm, simulate_spgemm,
                                   simulate_standard_spgemm)

__all__ = [
    "SpGemmPlan", "build_spgemm_plan",
    "simulate_nap_spgemm", "simulate_standard_spgemm", "simulate_spgemm",
    "CompiledSpGemm", "compile_spgemm", "spgemm_shardmap",
    "distributed_spgemm", "pack_b_values", "unpack_c_values",
    "clear_spgemm_cache", "shardmap_spgemm_runs",
    "galerkin_rap", "distributed_rap", "assert_matches_host",
]
