"""The paper's own experiment configuration (Sec. 5 defaults).

Not an LM architecture: this configures the NAPSpMV experiments — problem
generators, topology, partitions — mirroring the Blue Waters runs at
laptop-simulation scale.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SpMVExperimentConfig:
    n_nodes: int = 32
    ppn: int = 16                       # Blue Waters XE: 16 cores/node
    pairing: str = "balanced"           # paper's T/U rule ("aligned" for TPU)
    bytes_per_val: int = 8              # f64 payloads, as MPI would send
    machine: str = "blue_waters"        # cost-model parameter set
    # problem families (Sec. 5)
    anisotropic_grid: int = 96          # rotated anisotropic 2D
    elasticity_grid: int = 48           # Q1 linear elasticity (2 dof/node)
    random_rows_per_proc: int = 1000    # weak scaling rows/process
    random_nnz_per_row: Tuple[int, ...] = (25, 50, 100)
    strong_scale_rows: int = 64_000     # scaled-down from the paper's 4.096M


CONFIG = SpMVExperimentConfig()
