"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attn block
[arXiv:2411.15242; hf].  54 mamba layers, shared block every 6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    shared_attn_every=6, tie_embeddings=True,
    grad_accum=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_head=16, d_ff=128, vocab=512,
                          ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                          shared_attn_every=2,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False)
