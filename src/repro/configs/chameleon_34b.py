"""chameleon-34b [vlm] — early-fusion, VQ image tokens (frontend stub: the
input is already a mixed text/image token stream), qk-norm
[arXiv:2405.09818; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65_536,
    qk_norm=True, tie_embeddings=False,
    grad_accum=8,
    opt_state_dtype="int8",  # 8-bit Adam moments (fp32 master kept)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, grad_accum=1,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False)
