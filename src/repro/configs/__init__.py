"""Architecture registry: ``get_config(name)`` + the shape grid."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "gemma2_2b", "llama3_405b", "gemma2_27b", "gemma2_9b",
    "qwen3_moe_235b_a22b", "deepseek_v2_236b", "whisper_small",
    "chameleon_34b", "zamba2_2p7b", "rwkv6_3b",
]

# canonical dashed ids from the assignment -> module names
ALIASES: Dict[str, str] = {
    "gemma2-2b": "gemma2_2b", "llama3-405b": "llama3_405b",
    "gemma2-27b": "gemma2_27b", "gemma2-9b": "gemma2_9b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v2-236b": "deepseek_v2_236b", "whisper-small": "whisper_small",
    "chameleon-34b": "chameleon_34b", "zamba2-2.7b": "zamba2_2p7b",
    "rwkv6-3b": "rwkv6_3b",
}


def _module(name: str):
    mod = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def all_arch_ids() -> List[str]:
    return list(ALIASES.keys())
