"""The assigned input-shape grid (4 shapes x 10 archs = 40 cells).

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache
of ``seq_len``); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers
``prefill_step``.  ``long_500k`` requires sub-quadratic attention: it RUNS
for the SSM/hybrid archs (rwkv6-3b, zamba2-2.7b) and is a documented SKIP for
the pure full-attention archs (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs whose sequence mixer is sub-quadratic in context (state-space):
SUBQUADRATIC = {"rwkv6-3b", "zamba2-2.7b"}


def cell_runnable(arch: str, shape: str) -> Tuple[bool, Optional[str]]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{arch} is full-attention (documented skip)")
    return True, None


def all_cells() -> List[Tuple[str, str]]:
    from repro.configs import all_arch_ids
    return [(a, s) for a in all_arch_ids() for s in SHAPES]
