"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].  First layer dense (d_ff 12288)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=12288,              # dense (first) layer hidden
    vocab=102_400,
    mla_kv_lora=512, mla_q_lora=1536, mla_rope_dim=64,
    mla_v_head=128, mla_qk_nope=128,
    n_experts=160, top_k=6, moe_dff=1536, n_shared_experts=2,
    first_dense_layers=1, tie_embeddings=False,
    grad_accum=8,
    opt_state_dtype="int8",  # 8-bit Adam moments (fp32 master kept)
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                          d_head=16, d_ff=128, vocab=512,
                          mla_kv_lora=32, mla_q_lora=48, mla_rope_dim=8,
                          mla_v_head=16, mla_qk_nope=16,
                          n_experts=8, top_k=2, moe_dff=64,
                          n_shared_experts=1, first_dense_layers=1,
                          grad_accum=1, attn_block_q=32, attn_block_kv=32,
                          xent_chunk=32, dtype="float32", remat=False)
