"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,  # (attn-free)
    d_ff=8960, vocab=65_536,
    rwkv_head_size=64, tie_embeddings=True,
    grad_accum=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=512, rwkv_head_size=16,
                          xent_chunk=32, dtype="float32", remat=False)
