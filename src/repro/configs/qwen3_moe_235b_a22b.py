"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, qk-norm [hf:Qwen/Qwen3-235B-A22B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151_936,
    n_experts=128, top_k=8, moe_dff=1536,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
    grad_accum=8,
    opt_state_dtype="int8",  # 8-bit Adam moments (fp32 master kept)
    # production dispatch intent: resolve flat-vs-nap per geometry from
    # modeled inter-pod bytes, bf16 payloads on the dispatch wire
    # (repro/moe/README.md documents the error budgets)
    moe_dispatch="auto", wire_dtype="bf16",
)


def reduced() -> ModelConfig:
    # pins flat/f32 dispatch: the reduced config is the deterministic
    # bitwise baseline the tier-1 tests and benchmarks compare against
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=96, vocab=512, n_experts=8, top_k=2,
                          moe_dff=96, grad_accum=1,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False,
                          moe_dispatch="flat", wire_dtype="f32")
