"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab=128_256,
    rope_theta=500_000.0, tie_embeddings=False,
    grad_accum=16,   # activation memory: 1M-token global batch needs microbatching
    # 8-bit Adam moments + no fp32 master: 8 B/param total optimizer+grad
    # footprint -> 405B fits ONE 256-chip pod (EXPERIMENTS.md memory table)
    opt_state_dtype="int8", opt_master_fp32=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=8, n_kv_heads=2,
                          d_head=8, d_ff=192, vocab=512, grad_accum=2,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False)
