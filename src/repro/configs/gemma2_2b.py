"""gemma2-2b [dense] — local+global alternating, logit softcap [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab=256_000,
    sliding_window=4096, alt_local_global=True,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    embed_scale=True, tie_embeddings=True,
    grad_accum=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          d_head=16, d_ff=128, vocab=512, sliding_window=16,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False)
