"""whisper-small [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

input_specs feeds precomputed frame embeddings [B, 1500, 768]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=51_865,
    encoder_layers=12, encoder_seq=1500, is_encoder_decoder=True,
    tie_embeddings=True,
    grad_accum=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_head=16, d_ff=128, vocab=512,
                          encoder_layers=2, encoder_seq=30,
                          attn_block_q=32, attn_block_kv=32, xent_chunk=32,
                          dtype="float32", remat=False)
