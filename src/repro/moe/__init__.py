"""First-class MoE NAP-dispatch subsystem.

Token -> expert routing compiled into the repo's NAP plan machinery
(:mod:`repro.moe.plan`), quantized wire payload codecs + error-budget
oracles (:mod:`repro.moe.wire`), and the in-graph / registered-executor
dispatch paths (:mod:`repro.moe.dispatch`).  See README.md in this
directory for the mode and wire-dtype contracts.

Importing this package pulls only numpy; the jax-facing dispatch
symbols resolve lazily so the plan and wire layers (and the
``backend="moe"`` simulate executors built on them) work on a jax-free
installation.
"""
from repro.moe.plan import (DISPATCH_MODES, DISPATCH_PREFERENCE,
                            build_dispatch_plans, choose_dispatch,
                            dispatch_partitions, dispatch_traffic,
                            dispatch_verdict, representative_routing,
                            routing_matrix)
from repro.moe.wire import (FP8_MAX, WIRE_DTYPES, QuantSimWire,
                            check_wire_dtype, corrupt_wire_np,
                            decode_np, dispatch_error_budget, encode_np,
                            make_wire, quantize_np, wire_bytes,
                            wire_error_bound, wire_eps)

__all__ = [
    # plan layer
    "DISPATCH_MODES", "DISPATCH_PREFERENCE", "routing_matrix",
    "dispatch_partitions", "build_dispatch_plans", "dispatch_traffic",
    "dispatch_verdict", "choose_dispatch", "representative_routing",
    # wire layer
    "WIRE_DTYPES", "FP8_MAX", "check_wire_dtype", "wire_bytes", "wire_eps",
    "encode_np", "decode_np", "quantize_np", "wire_error_bound",
    "dispatch_error_budget", "corrupt_wire_np", "QuantSimWire", "make_wire",
    # dispatch layer (lazy; needs jax)
    "EPInfo", "moe_apply_sharded", "dispatch_operator",
    "resolve_dispatch_mode", "topology_of_mesh",
]

_DISPATCH_SYMBOLS = ("EPInfo", "moe_apply_sharded", "dispatch_operator",
                     "resolve_dispatch_mode", "topology_of_mesh")


def __getattr__(name):
    if name in _DISPATCH_SYMBOLS:
        from repro.moe import dispatch as _dispatch
        return getattr(_dispatch, name)
    raise AttributeError(f"module 'repro.moe' has no attribute {name!r}")
