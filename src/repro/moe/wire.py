"""Quantized wire codecs for the MoE dispatch subsystem.

The paper's NAP exchange cuts inter-node traffic by sending each value
ONCE per destination node; this module cuts the bytes of the value
itself.  A dispatch payload is encoded to a narrow wire dtype at the
pack boundary (the gateway that builds the per-destination send buffer),
ships through every hop in that form, and is decoded back to f32 on the
receive side before any accumulation — so the two levers compound:
fewer values on the expensive axis, and fewer bytes per value.

Wire dtypes::

    f32       4 B/value  identity codec — the program is bit-for-bit the
                         unquantized one (no cast is ever inserted)
    bf16      2 B/value  round-to-nearest bfloat16 (8-bit significand)
    fp8_e4m3  1 B/value  float8 e4m3fn, clipped to +-FP8_MAX before the
                         cast (e4m3fn overflows to NaN, not inf)

Error model (the budget the tests assert against the float64
simulator): one encode/decode roundtrip perturbs a value x by at most
``u * |x| + d`` where ``u`` is the wire dtype's unit roundoff and ``d``
half its smallest subnormal step (the absolute floor that matters for
fp8's narrow range).  A dispatch-sum ``y_e = sum_t w_et x_t`` whose x
payloads crossed the wire ``hops`` times is therefore off by at most
``hops * (u * (|W| @ |x|)_e + d * (|W| @ 1)_e)`` — see
:func:`dispatch_error_budget`.  Quantization is IDEMPOTENT (re-encoding
a decoded wire value reproduces the same wire word), so relaying an
already-quantized payload through the intra-node phases adds nothing;
only genuine re-accumulation points (the nap combine's local
gather-back) count as extra hops.

This module is numpy-only at import; the in-graph codecs
(:func:`encode_jnp` / :func:`decode_jnp`) import jax lazily so the
simulate/plan layers stay usable on a jax-free installation.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.integrity import Mismatch, MessageFault, SimWire, checksum_np

__all__ = [
    "WIRE_DTYPES", "FP8_MAX", "check_wire_dtype", "wire_bytes", "wire_eps",
    "encode_np", "decode_np", "quantize_np", "encode_jnp", "decode_jnp",
    "wire_error_bound", "dispatch_error_budget", "corrupt_wire_np",
    "QuantSimWire", "make_wire",
]

#: Supported wire encodings, in preference order (widest first).
WIRE_DTYPES: Tuple[str, ...] = ("f32", "bf16", "fp8_e4m3")

#: Largest finite float8_e4m3fn magnitude; encode clips to this so
#: out-of-range values saturate instead of becoming NaN.
FP8_MAX = 448.0

_WIRE_BYTES: Dict[str, int] = {"f32": 4, "bf16": 2, "fp8_e4m3": 1}

#: (unit roundoff u, half min-subnormal d) per wire dtype.  f32 is the
#: identity codec — it adds NO wire error (the program never casts).
_WIRE_EPS: Dict[str, Tuple[float, float]] = {
    "f32": (0.0, 0.0),
    "bf16": (2.0 ** -8, 0.0),        # 8-bit significand; subnormals ~2^-133
    "fp8_e4m3": (2.0 ** -4, 2.0 ** -10),  # 4-bit significand; min subnormal 2^-9
}


def check_wire_dtype(wire_dtype: str) -> str:
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"wire_dtype must be one of {'|'.join(WIRE_DTYPES)}, "
            f"got {wire_dtype!r}")
    return wire_dtype


def wire_bytes(wire_dtype: str) -> int:
    """Bytes per value on the wire (what planned_traffic charges)."""
    return _WIRE_BYTES[check_wire_dtype(wire_dtype)]


def wire_eps(wire_dtype: str) -> Tuple[float, float]:
    """(unit roundoff, half min-subnormal) of one encode/decode roundtrip."""
    return _WIRE_EPS[check_wire_dtype(wire_dtype)]


# ---------------------------------------------------------------------------
# numpy codecs (simulate backend / plan layer / oracles)
# ---------------------------------------------------------------------------

def _np_wire_dtype(wire_dtype: str):
    import ml_dtypes
    return {"bf16": ml_dtypes.bfloat16,
            "fp8_e4m3": ml_dtypes.float8_e4m3fn}[wire_dtype]


def encode_np(values: np.ndarray, wire_dtype: str) -> np.ndarray:
    """Encode a float payload into its wire representation.

    ``f32`` returns the input UNTOUCHED (identity, not a cast) — the
    bit-identity contract of the default wire.
    """
    check_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return values
    v = np.asarray(values)
    if wire_dtype == "fp8_e4m3":
        v = np.clip(v, -FP8_MAX, FP8_MAX)
    return v.astype(_np_wire_dtype(wire_dtype))


def decode_np(wire_values: np.ndarray, wire_dtype: str,
              out_dtype=np.float64) -> np.ndarray:
    """Decode wire words back to an accumulation dtype (f64 default —
    the simulators accumulate at full width)."""
    check_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return wire_values
    return np.asarray(wire_values).astype(out_dtype)


def quantize_np(values: np.ndarray, wire_dtype: str) -> np.ndarray:
    """One encode/decode roundtrip in the input's own dtype — what a
    receiver accumulates after the payload crossed the wire once."""
    if wire_dtype == "f32":
        return values
    v = np.asarray(values)
    return decode_np(encode_np(v, wire_dtype), wire_dtype, out_dtype=v.dtype)


# ---------------------------------------------------------------------------
# in-graph codecs (shard_map dispatch path; lazy jax import)
# ---------------------------------------------------------------------------

def jnp_wire_dtype(wire_dtype: str):
    """The jnp dtype a wire encoding ships as (None for the f32 identity)."""
    check_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return None
    import jax.numpy as jnp
    return {"bf16": jnp.bfloat16, "fp8_e4m3": jnp.float8_e4m3fn}[wire_dtype]


def encode_jnp(x, wire_dtype: str):
    """In-graph encode at the pack boundary.  ``f32`` inserts NOTHING —
    the jaxpr is identical to the unquantized program."""
    wd = jnp_wire_dtype(wire_dtype)
    if wd is None:
        return x
    import jax.numpy as jnp
    if wire_dtype == "fp8_e4m3":
        x = jnp.clip(x, -FP8_MAX, FP8_MAX)
    return x.astype(wd)


def decode_jnp(q, wire_dtype: str, out_dtype=None):
    """In-graph decode + promote to the accumulation dtype (f32 default)."""
    if wire_dtype == "f32":
        return q
    import jax.numpy as jnp
    return q.astype(out_dtype or jnp.float32)


# ---------------------------------------------------------------------------
# error-budget oracle
# ---------------------------------------------------------------------------

def wire_error_bound(cfg=None, *, wire_dtype: Optional[str] = None,
                     hops: Optional[int] = None) -> float:
    """Scalar relative error budget of quantized dispatch vs the float64
    simulator, relative to the dispatched mass ``max (|W| @ |x|)``.

    ``max|y_quant - y_oracle| <= wire_error_bound(cfg) * max(|W| @ |x|)
    + hops * d * max(|W| @ 1)`` — the second (absolute-floor) term only
    matters for fp8 and is folded in elementwise by
    :func:`dispatch_error_budget`; this scalar keeps a one-line assert
    honest for well-scaled inputs by returning ``hops * (u + d)``.

    Pass a :class:`repro.models.config.ModelConfig` (reads
    ``cfg.wire_dtype`` and derives hops from ``cfg.moe_dispatch`` — the
    nap combine re-accumulates at the pod gateway, so nap pays 2 hops
    worst-case, flat pays 1) or explicit ``wire_dtype=`` / ``hops=``.
    """
    if wire_dtype is None:
        wire_dtype = cfg.wire_dtype
    if hops is None:
        hops = 2 if (cfg is not None
                     and cfg.moe_dispatch in ("nap", "auto")) else 1
    u, d = wire_eps(wire_dtype)
    return float(hops) * (u + d)


def dispatch_error_budget(r, x: np.ndarray, wire_dtype: str,
                          hops: int = 1) -> np.ndarray:
    """Elementwise error budget for a dispatch-sum ``y = R @ x`` whose x
    payloads crossed the wire ``hops`` times.

    ``r`` is the CSR routing matrix (values = router weights), ``x`` the
    global token payload ``[T]`` or ``[T, nv]``.  Returns an array
    shaped like ``R @ x``: ``hops * (u * (|R| @ |x|) + d * (|R| @ 1))``
    plus a tiny floor so an exactly-zero row never asserts on noise.
    """
    u, d = wire_eps(wire_dtype)
    import dataclasses
    r_abs = dataclasses.replace(r, data=np.abs(r.data))
    x = np.asarray(x, dtype=np.float64)

    def mass(col: np.ndarray) -> np.ndarray:
        return r_abs.matvec(np.abs(col))

    if x.ndim == 1:
        m = mass(x)
    else:
        m = np.stack([mass(x[:, i]) for i in range(x.shape[1])], axis=1)
    ones = r_abs.matvec(np.ones(r.shape[1]))
    wmass = ones if x.ndim == 1 else ones[:, None]
    return float(hops) * (u * m + d * wmass) + 1e-12


# ---------------------------------------------------------------------------
# integrity over quantized words
# ---------------------------------------------------------------------------

def corrupt_wire_np(wire_values: np.ndarray, kind: str, element: int = 0,
                    bit: int = 0,
                    other: Optional[np.ndarray] = None) -> np.ndarray:
    """Fault transform applied WITHIN the wire words (the quantized
    payload is what travels, so that is what a transport fault hits).
    A ``bitflip`` flips a bit of the element's own wire word — 16 bits
    wide for bf16, 8 for fp8 — instead of a 32-bit float word."""
    from repro.core.integrity import corrupt_payload_np
    v = np.array(wire_values, copy=True)
    if kind != "bitflip":
        return corrupt_payload_np(v, kind, element, bit, other=other)
    flat = v.reshape(-1)
    e = int(element) % max(flat.size, 1)
    width = flat.dtype.itemsize * 8
    word = flat[e: e + 1].view({8: np.uint8, 16: np.uint16,
                                32: np.uint32, 64: np.uint64}[width])
    word ^= word.dtype.type(1) << word.dtype.type(int(bit) % width)
    return v


class QuantSimWire(SimWire):
    """Quantizing wire for the numpy message simulators.

    ``send``: encode the payload to the wire dtype, checksum the
    QUANTIZED words (the Fletcher fold views any dtype as raw bytes),
    apply a matching scripted fault to the wire words, and hand the
    decoded f64 values back to the mailbox.  ``recv``: RE-encode the
    received values (idempotent — reproduces the wire words bit-for-bit,
    including corrupted ones) and compare checksums.  So
    ``integrity="detect"|"recover"`` attributes and retries quantized
    messages exactly as it does f32 ones, with zero side-channel growth:
    still one u32 per message.
    """

    def __init__(self, topo, wire_dtype: str,
                 faults: Sequence[MessageFault] = ()) -> None:
        super().__init__(topo, faults)
        self.wire_dtype = check_wire_dtype(wire_dtype)

    def send(self, phase: str, msg, values: np.ndarray) -> np.ndarray:
        q = encode_np(values, self.wire_dtype)
        self.sent[(phase, msg.src, msg.dst)] = checksum_np(q)
        fault = self._match(phase, msg.src, msg.dst)
        prev = self.last_payload.get((phase, msg.src))
        self.last_payload[(phase, msg.src)] = np.array(q, copy=True)
        if fault is not None:
            self.injected += 1
            q = corrupt_wire_np(q, fault.kind, fault.element, fault.bit,
                                other=prev)
        return decode_np(q, self.wire_dtype,
                         out_dtype=np.asarray(values).dtype)

    def recv(self, phase: str, msg, values: np.ndarray) -> None:
        self.checks += 1
        q = encode_np(values, self.wire_dtype)
        if checksum_np(q) == self.sent[(phase, msg.src, msg.dst)]:
            return
        from repro.core.integrity import scope_for
        slot = (self.topo.node_of(msg.src) if phase == "inter"
                else msg.src if phase in ("pair", "direct")
                else self.topo.local_of(msg.src))
        self.mismatches.append(Mismatch(
            check="wire", phase=phase,
            scope=scope_for(phase, self.topo.node_of(msg.dst),
                            self.topo.local_of(msg.dst), slot,
                            self.topo.ppn),
            node=self.topo.node_of(msg.dst), proc=self.topo.local_of(msg.dst),
            slot=slot, direction="forward"))


def make_wire(topo, wire_dtype: str, faults: Sequence[MessageFault] = (),
              force: bool = False) -> Optional[SimWire]:
    """The wire a simulate apply threads through its mailboxes.

    f32 with no faults and ``force=False`` returns ``None`` (the
    uninstrumented simulators — bit-identical to the pre-wire path);
    f32 with faults or ``force=True`` (integrity armed) returns the
    plain :class:`SimWire` (full-width f64 checksums, today's
    behavior); narrow dtypes always get the quantizing wire so the
    payload is degraded whether or not integrity is on.
    """
    check_wire_dtype(wire_dtype)
    if wire_dtype == "f32":
        return SimWire(topo, faults) if (faults or force) else None
    return QuantSimWire(topo, wire_dtype, faults)
