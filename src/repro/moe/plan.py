"""Token -> expert routing compiled into the NAP plan machinery.

MoE dispatch IS a distributed SpMV exchange: a concrete top-k routing
``(ids [T, K], weights [T, K])`` becomes the sparse routing matrix
``R [E, T]`` (values = router weights), and then

* the **dispatch** communication is exactly R's forward x-exchange —
  every chip owning an expert must receive the x payload of every token
  routed to it, and the paper's E(n, m) dedup applies verbatim: a token
  bound for several experts of one remote pod crosses the inter-pod
  boundary ONCE under the nap plan, K times under the flat one;
* the weighted **dispatch-sum** ``R @ X`` (multi-RHS, nv = d_model) is
  the float64-checkable linear surrogate the oracle tests run, and the
  weighted **combine** is its transpose ``R.T @ Y`` — the same plan with
  every message reversed.

Layout contract (matches the in-graph shard_map dispatch of
:mod:`repro.moe.dispatch`): experts are laid out pod-major contiguous
(global chip ``c = pod * chips_per_pod + inner`` holds experts
``[c * E_loc, (c+1) * E_loc)``), and tokens are laid out contiguously
over their gateway chips, so ``Topology(n_nodes=n_pods,
ppn=chips_per_pod)`` with two contiguous partitions reproduces the
island's communication pattern on the host.

``choose_dispatch`` is the ``choose_comm``-style per-direction verdict:
flat vs nap scored lexicographically on modeled injected inter-pod
bytes (quantized wire width included), then postal time, then the nap
preference — dispatch and combine can disagree, exactly like the
forward/transpose split of the SpMV autotuner.

Numpy-only: safe to call at trace time (the in-graph ``"auto"`` mode
resolves through :func:`choose_dispatch` on a seeded representative
routing) and on a jax-free installation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.comm.cost import planned_traffic
from repro.core.comm_graph import (build_nap_plan, build_standard_plan,
                                   nap_stats, standard_stats)
from repro.core.cost_model import (PostalParams, TPU_V5E_POSTAL,
                                   postal_comm_time)
from repro.core.partition import RowPartition, contiguous_partition
from repro.core.topology import Topology
from repro.moe.wire import check_wire_dtype
from repro.sparse import CSR

__all__ = [
    "DISPATCH_MODES", "DISPATCH_PREFERENCE", "routing_matrix",
    "dispatch_partitions", "build_dispatch_plans", "dispatch_traffic",
    "dispatch_verdict", "choose_dispatch", "representative_routing",
]

#: Dispatch executor methods; "auto" resolves to one of the other two.
DISPATCH_MODES: Tuple[str, ...] = ("flat", "nap", "auto")

#: Tie-break order for the verdict (the paper's strategy wins exact ties).
DISPATCH_PREFERENCE: Tuple[str, ...] = ("nap", "flat")


def routing_matrix(ids: np.ndarray, weights: np.ndarray,
                   n_experts: int) -> CSR:
    """Build the CSR routing matrix ``R [E, T]`` from top-k routing.

    ``ids [T, K]`` are global expert ids, ``weights [T, K]`` the router
    weights; a negative id marks a padded/dropped choice and is skipped.
    Duplicate (expert, token) pairs sum — the dispatch-sum semantics of
    a token that picked the same expert twice.
    """
    ids = np.asarray(ids)
    weights = np.asarray(weights, dtype=np.float64)
    if ids.shape != weights.shape or ids.ndim != 2:
        raise ValueError(f"ids/weights must both be [T, K], got "
                         f"{ids.shape} vs {weights.shape}")
    T = ids.shape[0]
    keep = ids >= 0
    tok = np.broadcast_to(np.arange(T)[:, None], ids.shape)[keep]
    exp = ids[keep].astype(np.int64)
    if exp.size and exp.max() >= n_experts:
        raise ValueError(f"expert id {int(exp.max())} out of range "
                         f"[0, {n_experts})")
    return CSR.from_coo(exp, tok, weights[keep], (n_experts, T))


def dispatch_partitions(n_experts: int, n_tokens: int,
                        topo: Topology) -> Tuple[RowPartition, RowPartition]:
    """(expert_part, token_part) matching the island's pod-major layout."""
    if n_experts % topo.n_procs:
        raise ValueError(f"n_experts={n_experts} must divide over "
                         f"{topo.n_procs} chips (pod-major contiguous "
                         f"expert layout)")
    return (contiguous_partition(n_experts, topo.n_procs),
            contiguous_partition(n_tokens, topo.n_procs))


def build_dispatch_plans(r: CSR, expert_part: RowPartition,
                         token_part: RowPartition, topo: Topology,
                         pairing: str = "aligned") -> Dict[str, object]:
    """One plan per dispatch mode, from the same routing structure.

    ``flat`` is the standard Algorithm-1 pairwise exchange (every
    (token, owning-chip) pair crosses directly); ``nap`` the three-step
    node-aware plan (intra-pod gather to the gateway, ONE aggregated
    inter-pod exchange, intra-pod scatter to the owning chip).
    """
    return {
        "flat": build_standard_plan(r.indptr, r.indices, expert_part, topo,
                                    col_part=token_part),
        "nap": build_nap_plan(r.indptr, r.indices, expert_part, topo,
                              pairing=pairing, col_part=token_part),
    }


def dispatch_traffic(plan, wire_dtype: str = "f32", nv: int = 1,
                     direction: str = "forward",
                     integrity: str = "off") -> Dict:
    """Slot-granular modeled traffic of one dispatch plan at the wire
    width (``direction="forward"`` is dispatch, ``"transpose"`` the
    weighted combine over the reversed messages)."""
    check_wire_dtype(wire_dtype)
    return planned_traffic(plan, nv=nv, direction=direction,
                           integrity=integrity, wire_dtype=wire_dtype)


def dispatch_verdict(plans: Dict[str, object], direction: str = "forward",
                     wire_dtype: str = "f32", nv: int = 1,
                     integrity: str = "off",
                     params: PostalParams = TPU_V5E_POSTAL) -> Dict:
    """Score the flat/nap dispatch plans for ONE direction,
    lexicographically: injected inter-pod bytes, postal time, nap-first
    preference — the :func:`repro.comm.comm_verdict` rule over the
    dispatch candidate set."""
    candidates: Dict[str, Dict] = {}
    for name, plan in plans.items():
        traffic = dispatch_traffic(plan, wire_dtype=wire_dtype, nv=nv,
                                   direction=direction, integrity=integrity)
        times = postal_comm_time(traffic, params)
        candidates[name] = {
            "injected_inter_bytes": traffic["injected_inter_bytes"],
            "effective_inter_bytes": traffic["effective_inter_bytes"],
            "injected_intra_bytes": traffic["injected_intra_bytes"],
            "postal_time_s": times["total"],
        }
    chosen = min(
        candidates,
        key=lambda n: (candidates[n]["injected_inter_bytes"],
                       candidates[n]["postal_time_s"],
                       DISPATCH_PREFERENCE.index(n)))
    return {
        "chosen": chosen,
        "direction": direction,
        "wire_dtype": wire_dtype,
        "postal_params": params.name,
        "candidates": candidates,
    }


def choose_dispatch(r: CSR, expert_part: RowPartition,
                    token_part: RowPartition, topo: Topology,
                    wire_dtype: str = "f32", nv: int = 1,
                    integrity: str = "off",
                    params: PostalParams = TPU_V5E_POSTAL,
                    plans: Optional[Dict] = None) -> Dict:
    """Full per-direction dispatch verdict for one routing structure.

    Returns ``{"dispatch": verdict, "combine": verdict, "plans",
    "stats"}``; the two directions can disagree (the per-rank
    bottleneck flips when every message reverses), in which case the
    auto executor runs a different plan per direction.
    """
    if plans is None:
        plans = build_dispatch_plans(r, expert_part, token_part, topo)
    fwd = dispatch_verdict(plans, direction="forward",
                           wire_dtype=wire_dtype, nv=nv,
                           integrity=integrity, params=params)
    bwd = dispatch_verdict(plans, direction="transpose",
                           wire_dtype=wire_dtype, nv=nv,
                           integrity=integrity, params=params)
    return {
        "dispatch": fwd,
        "combine": bwd,
        "plans": plans,
        "stats": {
            "flat": {f"messages_{k}": v for k, v in
                     standard_stats(plans["flat"]).items()},
            "nap": {f"messages_{k}": v for k, v in
                    nap_stats(plans["nap"]).items()},
        },
    }


def representative_routing(n_tokens: int, n_experts: int, top_k: int,
                           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded uniform top-k routing ``(ids, weights)`` — the structure
    the ``"auto"`` mode models when the real routing is data-dependent
    (uniform expert choice is the capacity-factor design point the
    paper's T/U balancing assumes)."""
    k = min(top_k, n_experts)
    rng = np.random.default_rng(seed)
    scores = rng.random((n_tokens, n_experts))
    ids = np.argsort(-scores, axis=1)[:, :k].astype(np.int32)
    w = np.take_along_axis(scores, ids, axis=1)
    w = w / np.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    return ids, w
