"""First-class MoE NAP dispatch: the in-graph executors + operator entry.

This module is the home of the distributed MoE dispatch that used to be
private to ``models/moe.py``, promoted to a subsystem with two faces:

* :func:`moe_apply_sharded` — the in-graph shard_map path the LM stack
  (training ``examples/train_lm.py`` and serving) routes through.  The
  three modes mirror the paper: ``flat`` is the Algorithm-1 analogue
  (one capacity-padded all-to-all over the flat expert-parallel axes,
  every (token, expert-choice) copy crossing separately), ``nap`` the
  Algorithms-2+3 analogue (per-destination-POD dedup — a token bound
  for several experts on one remote pod crosses DCI once, the paper's
  E(n, m) — one aggregated inter-pod all-to-all, intra-pod fan-out,
  and the transpose route for the weighted combine), and ``auto``
  resolves per layer from the modeled injected inter-pod bytes of
  :func:`repro.moe.plan.choose_dispatch` at trace time.
* :func:`dispatch_operator` — compiles a CONCRETE token -> expert
  routing into the real NAP plan machinery through the executor
  registry (``backend="moe"``, methods ``flat | nap | auto`` in
  :mod:`repro.core.executors`): ``op @ x`` is the weighted
  dispatch-sum ``R @ X``, ``op.T @ y`` the weighted combine
  ``R.T @ Y``, with quantized wire payloads, slot-granular traffic
  accounting, postal cost, and the integrity surface
  (``detect``/``recover`` over checksums of the QUANTIZED words).

Wire quantization (``cfg.wire_dtype``, :mod:`repro.moe.wire`) encodes
the token payload ONCE at the pack boundary — the gateway that builds
the per-destination buffer — ships the narrow words through every hop
(the nap relay forwards wire words, it never re-rounds), and decodes to
f32 on the receive side before any accumulation.  The combine path
re-encodes at each genuine re-accumulation point (expert outputs onto
the inner wire, the pod gateway's local gather-back onto the DCI wire),
so nap pays at most 2 combine hops — the budget
:func:`repro.moe.wire.wire_error_bound` charges.  ``wire_dtype="f32"``
inserts NOTHING: the jaxpr is bit-for-bit the unquantized program.

Static-shape realisation is unchanged from the private implementation:
all buffers are capacity-padded; FIFO slots are assigned by cumsum and
overflowing copies are dropped (standard MoE token dropping;
capacity_factor controls the padding the paper's T/U balancing
minimises).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.topology import Topology
from repro.moe.plan import (DISPATCH_MODES, choose_dispatch,
                            dispatch_partitions, representative_routing,
                            routing_matrix)
from repro.moe.wire import check_wire_dtype, decode_jnp, encode_jnp

__all__ = [
    "EPInfo", "moe_apply_sharded", "dispatch_operator",
    "resolve_dispatch_mode", "topology_of_mesh",
]


# ---------------------------------------------------------------------------
# expert-parallel geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EPInfo:
    """Expert-parallel geometry: which mesh axes hold experts.

    axes ordering is (outer, inner) = (pod, model); single-pod meshes pass
    pod_axis=None and the nap mode degenerates to flat over `inner`.
    """
    inner_axis: str = "model"
    pod_axis: Optional[str] = None

    @property
    def manual_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + (self.inner_axis,)


def topology_of_mesh(mesh, ep: Optional[EPInfo] = None) -> Topology:
    """Map a device mesh's EP axes onto the plan layer's Topology:
    one "node" per pod, ``ppn`` inner (model) chips."""
    ep = ep or EPInfo(inner_axis="model", pod_axis="pod")
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_in = shape[ep.inner_axis]
    n_out = shape.get(ep.pod_axis, 1) if ep.pod_axis else 1
    return Topology(n_nodes=n_out, ppn=n_in)


# ---------------------------------------------------------------------------
# router / shared-expert pieces (referenced by models/moe.py's oracle too)
# ---------------------------------------------------------------------------

def _router(p, cfg, x2d: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Return (weights [T, K], expert ids [T, K]); normalized top-k softmax."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids.astype(jnp.int32)


def _shared_ffn(p, x):
    s = p["shared"]
    return (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]


# ---------------------------------------------------------------------------
# in-graph dispatch (shard_map; flat and nap modes, quantized wire)
# ---------------------------------------------------------------------------

def _a2a_wire(q: jax.Array, axes, wire_dtype: str) -> jax.Array:
    """``lax.all_to_all`` pinned to the wire dtype.

    XLA is free to hoist the receive-side decode across a collective —
    it folds ``convert(a2a(convert(x)))`` into an f32 exchange (same
    values, but the WIRE carries full-width words and the measured DCI
    bytes don't shrink; XLA:CPU even deletes optimization barriers
    placed around the collective).  Bitcasting the quantized payload to
    its same-width unsigned-integer WORDS defeats the fold: float
    converts cannot commute with an integer-typed collective, so the
    compiled all-to-all ships u16/u8.  The f32 identity path inserts
    nothing, preserving bit-identity with the pre-wire program.
    """
    if wire_dtype == "f32":
        return lax.all_to_all(q, axes, 0, 0, tiled=True)
    wdt = q.dtype
    words = lax.bitcast_convert_type(q, jnp.dtype(f"uint{wdt.itemsize * 8}"))
    out = lax.all_to_all(words, axes, 0, 0, tiled=True)
    return lax.bitcast_convert_type(out, wdt)


def _fifo_slots(need: jax.Array, capacity: int) -> jax.Array:
    """need [T, n_dst] bool -> slot [T, n_dst] in [0, capacity) or `capacity`
    (dropped; scatter mode='drop' discards it)."""
    slots = jnp.cumsum(need.astype(jnp.int32), axis=0) - 1
    return jnp.where(need & (slots < capacity), slots, capacity)


def _expert_compute(p_loc, cfg, tokens: jax.Array, meta_e: jax.Array,
                    meta_w: jax.Array, e_base: jax.Array, E_loc: int,
                    capacity: int) -> jax.Array:
    """Run this chip's experts over arrived copies.

    tokens [R, d]; meta_e [R, K] global expert ids (-1 pad); meta_w [R, K]
    router weights; e_base scalar — first global expert id on this chip.
    p_loc: expert weights [E_loc, d, ff] etc.
    Returns per-copy outputs [R, d] = sum over my experts hit by the copy.
    """
    R, d = tokens.shape
    out = jnp.zeros((R, d), jnp.float32)
    for el in range(E_loc):                      # static small loop
        gid = e_base + el
        hit = (meta_e == gid)
        w = (meta_w * hit).sum(-1)               # [R] combined weight
        need = hit.any(-1)
        slot = _fifo_slots(need[:, None], capacity)[:, 0]
        buf = jnp.zeros((capacity + 1, d), tokens.dtype).at[slot].set(
            tokens, mode="drop")[:capacity]
        h = jax.nn.silu(buf @ p_loc["w_gate"][el]) * (buf @ p_loc["w_up"][el])
        y = (h @ p_loc["w_down"][el]).astype(jnp.float32)
        back = jnp.where(slot[:, None] < capacity, y[jnp.minimum(slot, capacity - 1)], 0.0)
        out = out + back * w[:, None]
    return out


def resolve_dispatch_mode(cfg, n_pods: int, n_inner: int,
                          tokens_per_pod: int) -> Tuple[str, Dict]:
    """Resolve ``moe_dispatch="auto"`` from the modeled injected
    inter-pod bytes of a seeded representative routing (uniform expert
    choice at ``cfg.top_k`` — the capacity-factor design point).  Pure
    host numpy over static shapes, so it runs at trace time; memoized
    per geometry."""
    return _resolve_cached(cfg.n_experts, cfg.top_k, cfg.d_model,
                           getattr(cfg, "wire_dtype", "f32"),
                           n_pods, n_inner, tokens_per_pod)


@functools.lru_cache(maxsize=64)
def _resolve_cached(n_experts: int, top_k: int, d_model: int, wire_dtype: str,
                    n_pods: int, n_inner: int,
                    tokens_per_pod: int) -> Tuple[str, Dict]:
    topo = Topology(n_nodes=n_pods, ppn=n_inner)
    t_global = tokens_per_pod * n_pods
    ids, w = representative_routing(t_global, n_experts, top_k, seed=0)
    r = routing_matrix(ids, w, n_experts)
    expert_part, token_part = dispatch_partitions(n_experts, t_global, topo)
    v = choose_dispatch(r, expert_part, token_part, topo,
                        wire_dtype=wire_dtype, nv=d_model)
    return v["dispatch"]["chosen"], {"dispatch": v["dispatch"],
                                     "combine": v["combine"]}


def moe_apply_sharded(p, cfg, x: jax.Array, ep: EPInfo, mesh) -> jax.Array:
    """Distributed MoE: x [B, S, d] (batch sharded over dp axes, replicated
    over the EP axes); experts sharded over ep.manual_axes."""
    B, S, d = x.shape
    in_dtype = x.dtype

    def island(x_blk, router, w_gate, w_up, w_down):
        # f32 at the shard_map boundary: the transpose-of-replication psum
        # the autodiff inserts for x must be f32 — XLA:CPU's
        # all-reduce-promotion pass CHECK-fails on bf16 psums whose reduction
        # computation carries a trailing `copy` (backend bug, documented in
        # DESIGN.md); compute inside stays in the model dtype.
        y = _moe_island(cfg, ep, x_blk.astype(in_dtype), router,
                        w_gate, w_up, w_down)
        return y.astype(jnp.float32)

    from jax.sharding import PartitionSpec as P
    pod = ep.pod_axis
    x_spec = P(pod, None, None) if pod else P(None, None, None)
    e_spec = P(ep.manual_axes if pod else ep.inner_axis)
    out = compat.shard_map(
        island, mesh=mesh,
        in_specs=(x_spec, P(), e_spec, e_spec, e_spec),
        out_specs=x_spec,
        axis_names=set(ep.manual_axes),
        check_vma=False,
    )(x.astype(jnp.float32), p["router"], p["w_gate"], p["w_up"],
      p["w_down"]).astype(in_dtype)
    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x.reshape(-1, d)).reshape(B, S, d)
    return out


def _moe_island(cfg, ep, x, router, w_gate, w_up, w_down):
    """Manual-collective MoE over the EP axes; runs per (pod?, model) chip."""
    n_in = compat.axis_size(ep.inner_axis)
    n_out = compat.axis_size(ep.pod_axis) if ep.pod_axis else 1
    my_in = lax.axis_index(ep.inner_axis)
    my_out = lax.axis_index(ep.pod_axis) if ep.pod_axis else 0
    n_chips = n_in * n_out
    E, E_loc = cfg.n_experts, cfg.n_experts // n_chips
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    wd = check_wire_dtype(getattr(cfg, "wire_dtype", "f32"))

    # every inner-axis instance holds the same tokens (activations are
    # replicated over TP); instance m becomes the *gateway* for chunk m —
    # the paper's T/U distribution of node-level sends over local processes.
    Tc = T // n_in
    chunk = lax.dynamic_slice_in_dim(x2, my_in * Tc, Tc, 0)
    w, ids = _router({"router": router}, cfg, chunk)       # [Tc, K]
    K = cfg.top_k
    dst_chip = ids // E_loc                                # global EP chip
    # NB: global chip id c = pod * n_in + inner  (experts laid out pod-major)

    cap_factor = cfg.capacity_factor
    mode = cfg.moe_dispatch if (ep.pod_axis and n_out > 1) else "flat"
    if mode == "auto":
        # static-shape host resolution at trace time (modeled inter-pod
        # bytes on a representative routing; memoized per geometry)
        mode, _ = resolve_dispatch_mode(cfg, n_out, n_in, T)

    if mode == "flat":
        # ---- Algorithm 1 analogue: per-(token, k) copies, flat a2a --------
        capacity = max(1, int(Tc * K * cap_factor / n_chips))
        need = jnp.zeros((Tc, n_chips), bool)
        send_slot = jnp.full((Tc, K), capacity, jnp.int32)
        # sequential-k FIFO so each (t, k) copy gets its own slot
        counts = jnp.zeros((n_chips,), jnp.int32)
        toks = jnp.zeros((n_chips, capacity, d), x.dtype)
        meta_e = jnp.full((n_chips, capacity, K), -1, jnp.int32)
        meta_w = jnp.zeros((n_chips, capacity, K), jnp.float32)
        for k in range(K):                                  # static loop
            c = dst_chip[:, k]
            onehot = jax.nn.one_hot(c, n_chips, dtype=jnp.int32)
            slot = counts[None, :] + jnp.cumsum(onehot, 0) - onehot
            slot_k = (slot * onehot).sum(-1)                # [Tc]
            slot_k = jnp.where(slot_k < capacity, slot_k, capacity)
            toks = toks.at[c, slot_k].set(chunk, mode="drop")
            me = jnp.full((Tc, K), -1, jnp.int32).at[:, 0].set(ids[:, k])
            mw = jnp.zeros((Tc, K), jnp.float32).at[:, 0].set(w[:, k])
            meta_e = meta_e.at[c, slot_k].set(me, mode="drop")
            meta_w = meta_w.at[c, slot_k].set(mw, mode="drop")
            send_slot = send_slot.at[:, k].set(slot_k)
            counts = counts + onehot.sum(0)
        axes = ep.manual_axes if ep.pod_axis else ep.inner_axis
        # wire: encode at the pack boundary, ship narrow, f32 on receive
        r_toks = _a2a_wire(encode_jnp(toks, wd), axes, wd)
        r_me = lax.all_to_all(meta_e, axes, 0, 0, tiled=True)
        r_mw = lax.all_to_all(meta_w, axes, 0, 0, tiled=True)
        e_base = (my_out * n_in + my_in) * E_loc
        cap_e = max(1, int(Tc * K * cap_factor / E_loc))
        y = _expert_compute({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                            cfg, decode_jnp(r_toks, wd, x.dtype).reshape(-1, d),
                            r_me.reshape(-1, K), r_mw.reshape(-1, K),
                            e_base, E_loc, cap_e)
        # transpose route back: outputs in the same slots (y re-encoded —
        # expert outputs are a fresh payload for the return wire)
        y = decode_jnp(
            _a2a_wire(encode_jnp(y.reshape(n_chips, capacity, d), wd),
                      axes, wd), wd)
        out_chunk = jnp.zeros((Tc, d), jnp.float32)
        for k in range(K):
            c, s = dst_chip[:, k], send_slot[:, k]
            val = jnp.where((s < capacity)[:, None],
                            y[c, jnp.minimum(s, capacity - 1)], 0.0)
            out_chunk = out_chunk + val
    else:
        # ---- NAPSpMV 3-step: pod-dedup -> one DCI a2a -> local fan-out -----
        # dedup bound: a token crosses to pod o at most ONCE, so cap_pod = Tc
        # is exact (no drops at the DCI stage) — vs Tc*K/n_out copies in flat.
        cap_pod = Tc
        dst_pod = dst_chip // n_in
        need_pod = jnp.zeros((Tc, n_out), bool)
        for k in range(K):
            need_pod = need_pod | (dst_pod[:, k:k + 1] == jnp.arange(n_out)[None])
        pod_slot = _fifo_slots(need_pod, cap_pod)           # [Tc, n_out]
        toks = jnp.zeros((n_out, cap_pod, d), x.dtype)
        meta_e = jnp.full((n_out, cap_pod, K), -1, jnp.int32)
        meta_w = jnp.zeros((n_out, cap_pod, K), jnp.float32)
        for o in range(n_out):                              # static tiny loop
            sel = pod_slot[:, o]
            toks = toks.at[o, sel].set(chunk, mode="drop")
            # ship only the expert choices that live on pod o (E(n,m) dedup)
            on_o = dst_pod == o
            meta_e = meta_e.at[o, sel].set(jnp.where(on_o, ids, -1), mode="drop")
            meta_w = meta_w.at[o, sel].set(jnp.where(on_o, w, 0.0), mode="drop")
        # step 2: ONE aggregated inter-pod exchange (same inner slot pairing).
        # wire: the gateway encodes ONCE; the wire words relay through the
        # intra-pod fan-out below without re-rounding (codec idempotence).
        toks = _a2a_wire(encode_jnp(toks, wd), ep.pod_axis, wd)
        meta_e = lax.all_to_all(meta_e, ep.pod_axis, 0, 0, tiled=True)
        meta_w = lax.all_to_all(meta_w, ep.pod_axis, 0, 0, tiled=True)
        # step 3: fan out to owning chips within this pod
        R0 = n_out * cap_pod
        ft, fe, fw = (toks.reshape(R0, d), meta_e.reshape(R0, K),
                      meta_w.reshape(R0, K))
        cap_loc = max(1, int(Tc * K * cap_factor / n_in))
        loc_of = jnp.where(fe >= 0, (fe // E_loc) % n_in, -1)
        need_loc = jnp.zeros((R0, n_in), bool)
        for k in range(K):
            need_loc = need_loc | (loc_of[:, k:k + 1] == jnp.arange(n_in)[None])
        loc_slot = _fifo_slots(need_loc, cap_loc)
        lt = jnp.zeros((n_in, cap_loc, d), ft.dtype)   # stays in wire dtype
        le = jnp.full((n_in, cap_loc, K), -1, jnp.int32)
        lw = jnp.zeros((n_in, cap_loc, K), jnp.float32)
        for i in range(n_in):
            sel = loc_slot[:, i]
            on_i = loc_of == i
            lt = lt.at[i, sel].set(ft, mode="drop")
            le = le.at[i, sel].set(jnp.where(on_i, fe, -1), mode="drop")
            lw = lw.at[i, sel].set(jnp.where(on_i, fw, 0.0), mode="drop")
        lt = _a2a_wire(lt, ep.inner_axis, wd)
        le = lax.all_to_all(le, ep.inner_axis, 0, 0, tiled=True)
        lw = lax.all_to_all(lw, ep.inner_axis, 0, 0, tiled=True)
        e_base = (my_out * n_in + my_in) * E_loc
        cap_e = max(1, int(Tc * K * cap_factor / E_loc))
        y = _expert_compute({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                            cfg, decode_jnp(lt, wd, x.dtype).reshape(-1, d),
                            le.reshape(-1, K),
                            lw.reshape(-1, K), e_base, E_loc, cap_e)
        # ---- transpose route: local gather-back, pod a2a back, combine ----
        # each hop that re-accumulates re-encodes: expert outputs onto the
        # inner wire, the gateway's pod_back sum onto the DCI wire (the 2
        # combine hops wire_error_bound charges for nap).
        y = decode_jnp(
            _a2a_wire(encode_jnp(y.reshape(n_in, cap_loc, d), wd),
                      ep.inner_axis, wd),
            wd).reshape(n_in * cap_loc, d)
        # each original pod-copy slot sums its local fan-out returns
        pod_back = jnp.zeros((R0, d), jnp.float32)
        for i in range(n_in):
            sel = loc_slot[:, i]
            val = jnp.where((sel < cap_loc)[:, None],
                            y[i * cap_loc + jnp.minimum(sel, cap_loc - 1)], 0.0)
            pod_back = pod_back + val
        pod_back = decode_jnp(
            _a2a_wire(encode_jnp(pod_back.reshape(n_out, cap_pod, d), wd),
                      ep.pod_axis, wd), wd)
        out_chunk = jnp.zeros((Tc, d), jnp.float32)
        for o in range(n_out):
            sel = pod_slot[:, o]
            val = jnp.where((sel < cap_pod)[:, None],
                            pod_back[o, jnp.minimum(sel, cap_pod - 1)], 0.0)
            out_chunk = out_chunk + val

    # reassemble this pod's token set across its gateways (chunks were split
    # over the inner axis; pods hold different batch shards, no pod gather).
    # NB stays f32: a bf16 all_gather here transposes to a bf16 reduce-scatter
    # whose copy-rooted reduction trips the XLA:CPU promotion bug (see
    # moe_apply_sharded).
    full = lax.all_gather(out_chunk, ep.inner_axis, axis=0, tiled=True)
    return full.reshape(B, S, d)


# ---------------------------------------------------------------------------
# registered-executor entry: routing -> NAP plan machinery
# ---------------------------------------------------------------------------

def dispatch_operator(cfg, mesh=None, *, topo: Optional[Topology] = None,
                      n_tokens: Optional[int] = None, routing=None,
                      integrity: str = "off", seed: int = 0):
    """Compile token -> expert routing into a registered dispatch operator.

    Builds the CSR routing matrix ``R [E, T]`` (from ``routing=(ids
    [T, K], weights [T, K])``, or a seeded representative routing over
    ``n_tokens``) on the pod-major expert / gateway-contiguous token
    partitions, and binds the ``backend="moe"`` executor named by
    ``cfg.moe_dispatch`` through :func:`repro.api.operator` — so the
    full operator surface applies: ``op @ x`` is the weighted
    dispatch-sum (x payloads quantized to ``cfg.wire_dtype`` on every
    wire crossing, f32/f64 accumulated on receive), ``op.T @ y`` the
    weighted combine over the reversed plan, ``op.stats()`` the
    slot-granular quantized byte accounting, ``op.autotune_report()``
    the per-direction flat-vs-nap verdict (``method="auto"``), and
    ``integrity="detect"|"recover"`` checksums the quantized words.

    ``mesh`` maps its ("pod", "model") axes onto the plan topology;
    pass ``topo=Topology(n_pods, chips_per_pod)`` to pin one directly.
    """
    from repro import api as nap_api
    if cfg.moe_dispatch not in DISPATCH_MODES:
        raise ValueError(f"cfg.moe_dispatch must be one of "
                         f"{'|'.join(DISPATCH_MODES)}, "
                         f"got {cfg.moe_dispatch!r}")
    if topo is None:
        if mesh is None:
            raise ValueError("dispatch_operator needs a mesh (with "
                             "'pod'/'model' axes) or an explicit topo=")
        topo = topology_of_mesh(mesh)
    if routing is None:
        if n_tokens is None:
            raise ValueError("pass routing=(ids, weights) or n_tokens= for "
                             "a seeded representative routing")
        routing = representative_routing(n_tokens, cfg.n_experts, cfg.top_k,
                                         seed=seed)
    ids, weights = routing
    r = routing_matrix(np.asarray(ids), np.asarray(weights), cfg.n_experts)
    expert_part, token_part = dispatch_partitions(cfg.n_experts, r.shape[1],
                                                  topo)
    return nap_api.operator(r, topo=topo, row_part=expert_part,
                            col_part=token_part, backend="moe",
                            method=cfg.moe_dispatch,
                            wire_dtype=getattr(cfg, "wire_dtype", "f32"),
                            integrity=integrity)
