"""AMG V-cycle + (preconditioned) CG over NapOperator-backed SpMVs.

These exercise the hierarchy end-to-end; the *distributed* SpMV inside
each level is what the paper optimizes.  Every solver accepts either a
plain callable or a :class:`repro.api.NapOperator` (operators are
callable), and :func:`level_operators` builds a **fully distributed
hierarchy**: one square operator for each level's A *and one rectangular
operator for each P* (its ``.T`` view is the restriction), so the
V-cycle's grid transfers run as node-aware SpMVs too — ``P.T @ r``
through the reversed communication plan instead of a host-side gather.
``examples/amg_spmv.py`` wires the NAPSpMV executors into this loop with
no raw lambdas.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.amg.hierarchy import Level
from repro.core.integrity import IntegrityError
from repro.core.partition import contiguous_partition
from repro.sparse.csr import CSR


@dataclasses.dataclass
class LevelOperators:
    """The distributed operators of one hierarchy level.

    ``a`` — square NapOperator for A_l (row == col partition);
    ``p`` — RECTANGULAR NapOperator for the prolongation
    (row_part = level l's partition, col_part = level l+1's);
    ``r`` — the restriction, ``p.T``: the same compiled plan with
    send/recv roles reversed (never a second plan build).
    Any of the three is ``None`` where the level is too small to
    distribute; :func:`amg_vcycle` falls back to local matvecs there.
    """

    a: Optional[object] = None
    p: Optional[object] = None
    r: Optional[object] = None

    def galerkin(self, materialize: bool = False,
                 **materialize_kwargs) -> Optional[object]:
        """The coarse-grid operator ``R @ A @ P`` (None if any factor is).

        ``materialize=False`` (default) returns the lazy
        :class:`repro.api.ComposedOperator` — three chained node-aware
        SpMVs per apply.  ``materialize=True`` collapses the chain
        through the node-aware distributed SpGEMM into a CONCRETE
        :class:`repro.api.NapOperator` on the coarse partitions (one
        SpMV per apply; wins past a few applies — see
        ``src/repro/spgemm/README.md``).  Extra kwargs pass to
        :meth:`repro.api.ComposedOperator.materialize`.
        """
        if self.a is None or self.p is None or self.r is None:
            return None
        composed = self.r @ self.a @ self.p
        if not materialize:
            return composed
        return composed.materialize(**materialize_kwargs)


def level_operators(levels: Sequence[Level], topo, *, method: str = "nap",
                    backend: str = "simulate", min_rows: Optional[int] = None,
                    parts: Optional[Sequence] = None,
                    materialize: bool = False,
                    spgemm_backend: str = "simulate",
                    spgemm_dtype=None,
                    comm: Optional[str] = None,
                    **kwargs) -> List[LevelOperators]:
    """One :class:`LevelOperators` (A + rectangular P/R) per AMG level.

    ``parts`` optionally supplies one partition per level (defaults to
    ``contiguous_partition`` of each level's row count); level l's P uses
    ``row_part=parts[l], col_part=parts[l+1]``, so every composition
    interface in the V-cycle (``P.T @ r``, ``R @ A @ P``) chains with
    MATCHING partitions.  Levels with fewer rows than ``min_rows``
    (default: the machine size) get ``a=None``; their grid transfers stay
    distributed as long as the FINE side is large enough — the coarse
    partition simply has empty ranks.  Extra ``kwargs`` pass straight to
    :func:`repro.api.operator`.

    ``comm`` selects the exchange strategy PER LEVEL and PER DIRECTION:
    each level's A and P get their own :func:`repro.api.operator` call,
    so ``comm="auto"`` runs the comm autotuner against that level's own
    sparsity — a near-dense coarse level can resolve to ``"multistep"``
    (or ``"standard"``) while the fine levels stay ``"nap"``, and a
    rectangular P's restriction direction can differ from its forward.
    Inspect the per-level verdicts via each operator's
    ``autotune_report()["comm"]``.

    ``materialize=True`` assembles every coarse-level matrix through the
    node-aware distributed SpGEMM (:func:`repro.spgemm.galerkin_rap` on
    ``spgemm_backend``) instead of trusting the hierarchy's host-side
    product: each level's ``A_c = R (A P)`` chains from the previous
    distributed product and is cross-checked against the hierarchy's
    host ``csr_matmul`` assembly — bit-for-bit on the float64
    ``"simulate"`` backend, to round-off on ``"shardmap"`` — and the
    coarse operators are built FROM the distributed product.
    """
    import repro.api as nap  # local import keeps numpy-only users jax-free

    floor = topo.n_procs if min_rows is None else min_rows
    if parts is None:
        parts = [contiguous_partition(lvl.a.shape[0], topo.n_procs)
                 for lvl in levels]
    a_mats = [levels[0].a] + [None] * (len(levels) - 1)
    if materialize:
        from repro.spgemm import assert_matches_host, galerkin_rap
        for i in range(len(levels) - 1):
            lvl = levels[i]
            r_mat = lvl.r if lvl.r is not None else lvl.p.transpose()
            a_mats[i + 1] = galerkin_rap(
                r_mat, a_mats[i], lvl.p, parts[i], parts[i + 1], topo,
                method=method if method in ("nap", "standard") else "nap",
                backend=spgemm_backend, dtype=spgemm_dtype,
                mesh=kwargs.get("mesh"))
            # float32 products chain level-to-level, so the tolerance vs
            # the float64 host hierarchy grows with the chain depth
            assert_matches_host(a_mats[i + 1], levels[i + 1].a,
                                spgemm_backend, f"level {i + 1} A_c",
                                rtol=5e-5 * (i + 1))
    else:
        a_mats = [lvl.a for lvl in levels]
    ops: List[LevelOperators] = []
    for i, lvl in enumerate(levels):
        entry = LevelOperators()
        if lvl.a.shape[0] >= floor:
            entry.a = nap.operator(a_mats[i], topo=topo, part=parts[i],
                                   method=method, backend=backend,
                                   comm=comm, **kwargs)
            if lvl.p is not None:
                entry.p = nap.operator(lvl.p, topo=topo,
                                       row_part=parts[i],
                                       col_part=parts[i + 1],
                                       method=method, backend=backend,
                                       comm=comm, **kwargs)
                entry.r = entry.p.T
        ops.append(entry)
    return ops


def _level_entry(operators, lvl: int) -> Tuple[Optional[object],
                                               Optional[object],
                                               Optional[object]]:
    """(a_op, p_op, r_op) for one level; tolerates the legacy form where
    ``operators[lvl]`` is a bare A operator (or None)."""
    if operators is None or lvl >= len(operators):
        return None, None, None
    entry = operators[lvl]
    if entry is None:
        return None, None, None
    if isinstance(entry, LevelOperators):
        return entry.a, entry.p, entry.r
    return entry, None, None


def _diag(a: CSR) -> np.ndarray:
    rows, cols, vals = a.to_coo()
    d = np.zeros(a.shape[0])
    m = rows == cols
    d[rows[m]] = vals[m]
    d[d == 0] = 1.0
    return d


def jacobi(a: CSR, x: np.ndarray, b: np.ndarray, d: np.ndarray,
           sweeps: int = 2, omega: float = 2.0 / 3.0,
           spmv: Optional[Callable] = None) -> np.ndarray:
    """``spmv`` may be a callable or a NapOperator (operators are callable)."""
    mv = spmv or a.matvec
    for _ in range(sweeps):
        x = x + omega * (b - mv(x)) / d
    return x


def amg_vcycle(levels: List[Level], b: np.ndarray,
               x: Optional[np.ndarray] = None, lvl: int = 0,
               spmv_at: Optional[Callable[[int, np.ndarray], np.ndarray]] = None,
               operators: Optional[Sequence[Optional[object]]] = None
               ) -> np.ndarray:
    """One V(2,2)-cycle.

    Per-level SpMV resolution: ``operators[lvl]`` — a
    :class:`LevelOperators` from :func:`level_operators` (A plus the
    rectangular P/R, so restriction runs as the node-aware ``P.T @ r``
    and prolongation as ``P @ x_c``; ``None`` members fall back to the
    level's local matvecs), or legacy bare A operators — or the
    lower-level ``spmv_at(lvl, v)`` callback.
    """
    a = levels[lvl].a
    a_op = p_op = r_op = None
    if operators is not None and spmv_at is None:
        a_op, p_op, r_op = _level_entry(operators, lvl)
    if a_op is not None:
        mv = a_op
    elif spmv_at is not None:
        mv = lambda v: spmv_at(lvl, v)
    else:
        mv = a.matvec
    if x is None:
        x = np.zeros_like(b)
    if lvl == len(levels) - 1 or levels[lvl].p is None:
        dense = a.to_dense()
        return np.linalg.lstsq(dense, b, rcond=None)[0]
    d = _diag(a)
    x = jacobi(a, x, b, d, spmv=mv)
    res = b - mv(x)
    # restriction: the node-aware transpose SpMV (P.T against the SAME
    # compiled plan as prolongation) where distributed, else host matvec
    coarse_b = (r_op @ res) if r_op is not None else levels[lvl].r.matvec(res)
    coarse_x = amg_vcycle(levels, coarse_b, None, lvl + 1, spmv_at, operators)
    x = x + ((p_op @ coarse_x) if p_op is not None
             else levels[lvl].p.matvec(coarse_x))
    return jacobi(a, x, b, d, spmv=mv)


def cg_solve(a: CSR, b: np.ndarray, tol: float = 1e-8, maxiter: int = 500,
             precond: Optional[Callable[[np.ndarray], np.ndarray]] = None,
             spmv: Optional[Callable] = None,
             x0: Optional[np.ndarray] = None,
             callback: Optional[Callable[[int, np.ndarray], None]] = None,
             verify_every: int = 0, verify_tol: float = 1e-6):
    """(Preconditioned) conjugate gradients; returns (x, iters, relres).

    ``spmv`` may be a plain callable or a NapOperator.  ``x0`` warm-starts
    the iteration (the serve layer's elastic recovery restarts from the
    last checkpointed iterate); ``callback(it, x)`` fires after every
    iteration — raising from it aborts the solve mid-stream, which the
    fault harness uses to model a node dying at step k.  A restarted CG
    rebuilds its Krylov space from the checkpointed x, so iterate
    trajectories differ from an uninterrupted run, but any solve driven
    to ``tol`` satisfies the same residual contract.

    ``verify_every=k`` (0 = off; the default path is bit-identical to a
    build without the feature) adds a SELF-VERIFYING replay check every k
    iterations: the recursive residual ``r`` is compared against the true
    residual ``b - A x`` (one extra SpMV).  A silently corrupted SpMV
    poisons the recursion — the two drift apart far beyond float
    round-off — so on a drift past ``verify_tol`` (relative to ``||b||``)
    the solver rolls back to the LAST VERIFIED iterate and replays; a
    transient fault replays clean and the trajectory re-joins the
    fault-free one exactly.  Drift that persists at the same iterate
    raises :class:`repro.core.integrity.IntegrityError` (the corruption
    is not transient — retrying cannot help).
    """
    mv = spmv or a.matvec
    x = np.zeros_like(b) if x0 is None else np.array(x0, dtype=b.dtype)
    r = b - mv(x)
    z = precond(r) if precond else r
    p = z.copy()
    rz = float(r @ z)
    b_norm = max(float(np.linalg.norm(b)), 1e-30)
    rel = float(np.linalg.norm(r)) / b_norm
    if rel < tol:     # warm start already converged
        return x, 0, rel
    snap = (x.copy(), r.copy(), p.copy(), rz) if verify_every else None
    snap_it = 0
    failed_at = -1
    it = 1
    while it <= maxiter:
        ap = mv(p)
        alpha = rz / max(float(p @ ap), 1e-300)
        x += alpha * p
        r -= alpha * ap
        verified = False
        if verify_every and it % verify_every == 0:
            drift = float(np.linalg.norm((b - mv(x)) - r)) / b_norm
            if drift > verify_tol:
                if failed_at == it:
                    raise IntegrityError(
                        f"CG true-residual replay check failed twice at "
                        f"iteration {it} (drift {drift:.3e} > "
                        f"{verify_tol:.1e}): persistent SpMV corruption")
                failed_at = it
                x, r, p = snap[0].copy(), snap[1].copy(), snap[2].copy()
                rz = snap[3]
                it = snap_it + 1
                continue
            verified = True
            failed_at = -1
        if callback is not None:
            callback(it, x)
        rel = float(np.linalg.norm(r)) / b_norm
        if rel < tol:
            return x, it, rel
        z = precond(r) if precond else r
        rz_new = float(r @ z)
        p = z + (rz_new / max(rz, 1e-300)) * p
        rz = rz_new
        # snapshot AFTER the direction update: the saved tuple is the
        # complete loop-top state of iteration it+1, so a rollback replays
        # the clean trajectory exactly (a verify-point snapshot would pair
        # the new x/r with the PREVIOUS search direction)
        if verified:
            snap = (x.copy(), r.copy(), p.copy(), rz)
            snap_it = it
        it += 1
    return x, maxiter, float(np.linalg.norm(r)) / b_norm


def _safe_div(num: float, den: float) -> float:
    """num/den with a sign-preserving breakdown guard (BiCG denominators
    are legitimately negative — clamping with max() would flip search
    directions into garbage)."""
    if abs(den) < 1e-300:
        den = 1e-300 if den >= 0 else -1e-300
    return num / den


def bicgstab_solve(a: CSR, b: np.ndarray, tol: float = 1e-8,
                   maxiter: int = 500, spmv: Optional[Callable] = None,
                   spmv_t: Optional[Callable] = None,
                   verify_every: int = 0, verify_tol: float = 1e-6):
    """BiCG-stabilised solve for nonsymmetric systems; returns
    (x, iters, relres).

    BiCGSTAB itself needs only ``A @ v``, but the classic BiCG it
    stabilises needs ``A.T @ v`` — pass ``spmv_t`` (e.g. ``op.T``) to run
    plain BiCG instead, exercising the transpose SpMV the NapOperator
    front-end provides from the same compiled plan.

    ``verify_every=k`` adds the same true-residual replay check as
    :func:`cg_solve` (0 = off, default path untouched): drift between
    the recursive and true residual past ``verify_tol`` rolls back to
    the last verified iterate and replays; persistent drift at the same
    iterate raises :class:`repro.core.integrity.IntegrityError`.
    """
    mv = spmv or a.matvec
    x = np.zeros_like(b)
    r = b - mv(x)
    b_norm = max(float(np.linalg.norm(b)), 1e-30)

    def _check(it, x, r, failed_at) -> bool:
        """Shared replay check: True means drift past tolerance (roll
        back); a REPEAT failure at the same iterate raises instead —
        retrying cannot fix a persistent corruption."""
        drift = float(np.linalg.norm((b - mv(x)) - r)) / b_norm
        if drift <= verify_tol:
            return False
        if failed_at == it:
            raise IntegrityError(
                f"true-residual replay check failed twice at "
                f"iteration {it} (drift {drift:.3e} > "
                f"{verify_tol:.1e}): persistent SpMV corruption")
        return True

    if spmv_t is not None:
        # plain BiCG (Lanczos biorthogonalisation) using A and A.T
        rt = r.copy()
        p, pt = r.copy(), rt.copy()
        rho = float(rt @ r)
        snap = (x.copy(), r.copy(), rt.copy(), p.copy(), pt.copy(), rho) \
            if verify_every else None
        snap_it, failed_at, it = 0, -1, 1
        while it <= maxiter:
            ap = mv(p)
            alpha = _safe_div(rho, float(pt @ ap))
            x += alpha * p
            r -= alpha * ap
            verified = False
            if verify_every and it % verify_every == 0:
                if _check(it, x, r, failed_at):
                    failed_at = it
                    x, r, rt, p, pt = (s.copy() for s in snap[:5])
                    rho = snap[5]
                    it = snap_it + 1
                    continue
                verified, failed_at = True, -1
            rel = float(np.linalg.norm(r)) / b_norm
            if rel < tol:
                return x, it, rel
            rt = rt - alpha * spmv_t(pt)
            rho_new = float(rt @ r)
            beta = _safe_div(rho_new, rho)
            p = r + beta * p
            pt = rt + beta * pt
            rho = rho_new
            # snapshot AFTER the direction updates — the complete loop-top
            # state of iteration it+1, so a rollback replays exactly
            if verified:
                snap = (x.copy(), r.copy(), rt.copy(), p.copy(), pt.copy(),
                        rho)
                snap_it = it
            it += 1
        return x, maxiter, float(np.linalg.norm(r)) / b_norm
    rt0 = r.copy()
    rho = alpha = omega = 1.0
    v = p = np.zeros_like(b)
    snap = (x.copy(), r.copy(), v.copy(), p.copy(), rho, alpha, omega) \
        if verify_every else None
    snap_it, failed_at, it = 0, -1, 1
    while it <= maxiter:
        rho_new = float(rt0 @ r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        rho = rho_new
        p = r + beta * (p - omega * v)
        v = mv(p)
        alpha = _safe_div(rho, float(rt0 @ v))
        s = r - alpha * v
        t = mv(s)
        omega = _safe_div(float(t @ s), float(t @ t))
        x += alpha * p + omega * s
        r = s - omega * t
        if verify_every and it % verify_every == 0:
            if _check(it, x, r, failed_at):
                failed_at = it
                x, r, v, p = (s_.copy() for s_ in snap[:4])
                rho, alpha, omega = snap[4:]
                it = snap_it + 1
                continue
            failed_at = -1
            # BiCGSTAB updates every recurrence at the loop TOP, so the
            # verify-point state IS the loop-top state of iteration it+1
            snap = (x.copy(), r.copy(), v.copy(), p.copy(), rho, alpha,
                    omega)
            snap_it = it
        rel = float(np.linalg.norm(r)) / b_norm
        if rel < tol:
            return x, it, rel
        it += 1
    return x, maxiter, float(np.linalg.norm(r)) / b_norm
