"""AMG V-cycle + (preconditioned) CG, numpy reference solvers.

These exercise the hierarchy end-to-end; the *distributed* SpMV inside each
level is what the paper optimizes (examples/amg_spmv.py wires the NAPSpMV
executor into this loop).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.amg.hierarchy import Level
from repro.sparse.csr import CSR


def _diag(a: CSR) -> np.ndarray:
    rows, cols, vals = a.to_coo()
    d = np.zeros(a.shape[0])
    m = rows == cols
    d[rows[m]] = vals[m]
    d[d == 0] = 1.0
    return d


def jacobi(a: CSR, x: np.ndarray, b: np.ndarray, d: np.ndarray,
           sweeps: int = 2, omega: float = 2.0 / 3.0,
           spmv: Optional[Callable] = None) -> np.ndarray:
    mv = spmv or a.matvec
    for _ in range(sweeps):
        x = x + omega * (b - mv(x)) / d
    return x


def amg_vcycle(levels: List[Level], b: np.ndarray,
               x: Optional[np.ndarray] = None, lvl: int = 0,
               spmv_at: Optional[Callable[[int, np.ndarray], np.ndarray]] = None
               ) -> np.ndarray:
    """One V(2,2)-cycle.  ``spmv_at(lvl, v)`` may override the per-level SpMV
    (e.g. with the distributed NAP executor)."""
    a = levels[lvl].a
    mv = (lambda v: spmv_at(lvl, v)) if spmv_at else a.matvec
    if x is None:
        x = np.zeros_like(b)
    if lvl == len(levels) - 1 or levels[lvl].p is None:
        dense = a.to_dense()
        return np.linalg.lstsq(dense, b, rcond=None)[0]
    d = _diag(a)
    x = jacobi(a, x, b, d, spmv=mv)
    coarse_b = levels[lvl].r.matvec(b - mv(x))
    coarse_x = amg_vcycle(levels, coarse_b, None, lvl + 1, spmv_at)
    x = x + levels[lvl].p.matvec(coarse_x)
    return jacobi(a, x, b, d, spmv=mv)


def cg_solve(a: CSR, b: np.ndarray, tol: float = 1e-8, maxiter: int = 500,
             precond: Optional[Callable[[np.ndarray], np.ndarray]] = None,
             spmv: Optional[Callable] = None):
    """(Preconditioned) conjugate gradients; returns (x, iters, relres)."""
    mv = spmv or a.matvec
    x = np.zeros_like(b)
    r = b - mv(x)
    z = precond(r) if precond else r
    p = z.copy()
    rz = float(r @ z)
    b_norm = max(float(np.linalg.norm(b)), 1e-30)
    for it in range(1, maxiter + 1):
        ap = mv(p)
        alpha = rz / max(float(p @ ap), 1e-300)
        x += alpha * p
        r -= alpha * ap
        rel = float(np.linalg.norm(r)) / b_norm
        if rel < tol:
            return x, it, rel
        z = precond(r) if precond else r
        rz_new = float(r @ z)
        p = z + (rz_new / max(rz, 1e-300)) * p
        rz = rz_new
    return x, maxiter, float(np.linalg.norm(r)) / b_norm
