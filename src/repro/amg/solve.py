"""AMG V-cycle + (preconditioned) CG over NapOperator-backed SpMVs.

These exercise the hierarchy end-to-end; the *distributed* SpMV inside
each level is what the paper optimizes.  Every solver accepts either a
plain callable or a :class:`repro.api.NapOperator` (operators are
callable), and :func:`level_operators` builds one operator per hierarchy
level so AMG cycles run entirely through the unified front-end —
``examples/amg_spmv.py`` wires the NAPSpMV executors into this loop with
no raw lambdas.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.amg.hierarchy import Level
from repro.sparse.csr import CSR


def level_operators(levels: Sequence[Level], topo, *, method: str = "nap",
                    backend: str = "simulate", min_rows: Optional[int] = None,
                    **kwargs) -> List[Optional[object]]:
    """One :class:`repro.api.NapOperator` per AMG level.

    Levels smaller than ``min_rows`` (default: the machine size — a level
    cannot be distributed over more ranks than it has rows) get ``None``;
    :func:`amg_vcycle` falls back to the level's local ``a.matvec`` there.
    Extra ``kwargs`` pass straight to :func:`repro.api.operator`.
    """
    import repro.api as nap  # local import keeps numpy-only users jax-free

    floor = topo.n_procs if min_rows is None else min_rows
    ops: List[Optional[object]] = []
    for lvl in levels:
        if lvl.a.shape[0] < floor:
            ops.append(None)
            continue
        ops.append(nap.operator(lvl.a, topo=topo, method=method,
                                backend=backend, **kwargs))
    return ops


def _diag(a: CSR) -> np.ndarray:
    rows, cols, vals = a.to_coo()
    d = np.zeros(a.shape[0])
    m = rows == cols
    d[rows[m]] = vals[m]
    d[d == 0] = 1.0
    return d


def jacobi(a: CSR, x: np.ndarray, b: np.ndarray, d: np.ndarray,
           sweeps: int = 2, omega: float = 2.0 / 3.0,
           spmv: Optional[Callable] = None) -> np.ndarray:
    """``spmv`` may be a callable or a NapOperator (operators are callable)."""
    mv = spmv or a.matvec
    for _ in range(sweeps):
        x = x + omega * (b - mv(x)) / d
    return x


def amg_vcycle(levels: List[Level], b: np.ndarray,
               x: Optional[np.ndarray] = None, lvl: int = 0,
               spmv_at: Optional[Callable[[int, np.ndarray], np.ndarray]] = None,
               operators: Optional[Sequence[Optional[object]]] = None
               ) -> np.ndarray:
    """One V(2,2)-cycle.

    Per-level SpMV resolution: ``operators[lvl]`` (a NapOperator from
    :func:`level_operators`; ``None`` entries fall back to the level's
    ``a.matvec``) or the lower-level ``spmv_at(lvl, v)`` callback.
    """
    a = levels[lvl].a
    if operators is not None and spmv_at is None:
        op = operators[lvl] if lvl < len(operators) else None
        mv = op if op is not None else a.matvec
    elif spmv_at is not None:
        mv = lambda v: spmv_at(lvl, v)
    else:
        mv = a.matvec
    if x is None:
        x = np.zeros_like(b)
    if lvl == len(levels) - 1 or levels[lvl].p is None:
        dense = a.to_dense()
        return np.linalg.lstsq(dense, b, rcond=None)[0]
    d = _diag(a)
    x = jacobi(a, x, b, d, spmv=mv)
    coarse_b = levels[lvl].r.matvec(b - mv(x))
    coarse_x = amg_vcycle(levels, coarse_b, None, lvl + 1, spmv_at, operators)
    x = x + levels[lvl].p.matvec(coarse_x)
    return jacobi(a, x, b, d, spmv=mv)


def cg_solve(a: CSR, b: np.ndarray, tol: float = 1e-8, maxiter: int = 500,
             precond: Optional[Callable[[np.ndarray], np.ndarray]] = None,
             spmv: Optional[Callable] = None):
    """(Preconditioned) conjugate gradients; returns (x, iters, relres).

    ``spmv`` may be a plain callable or a NapOperator.
    """
    mv = spmv or a.matvec
    x = np.zeros_like(b)
    r = b - mv(x)
    z = precond(r) if precond else r
    p = z.copy()
    rz = float(r @ z)
    b_norm = max(float(np.linalg.norm(b)), 1e-30)
    for it in range(1, maxiter + 1):
        ap = mv(p)
        alpha = rz / max(float(p @ ap), 1e-300)
        x += alpha * p
        r -= alpha * ap
        rel = float(np.linalg.norm(r)) / b_norm
        if rel < tol:
            return x, it, rel
        z = precond(r) if precond else r
        rz_new = float(r @ z)
        p = z + (rz_new / max(rz, 1e-300)) * p
        rz = rz_new
    return x, maxiter, float(np.linalg.norm(r)) / b_norm


def _safe_div(num: float, den: float) -> float:
    """num/den with a sign-preserving breakdown guard (BiCG denominators
    are legitimately negative — clamping with max() would flip search
    directions into garbage)."""
    if abs(den) < 1e-300:
        den = 1e-300 if den >= 0 else -1e-300
    return num / den


def bicgstab_solve(a: CSR, b: np.ndarray, tol: float = 1e-8,
                   maxiter: int = 500, spmv: Optional[Callable] = None,
                   spmv_t: Optional[Callable] = None):
    """BiCG-stabilised solve for nonsymmetric systems; returns
    (x, iters, relres).

    BiCGSTAB itself needs only ``A @ v``, but the classic BiCG it
    stabilises needs ``A.T @ v`` — pass ``spmv_t`` (e.g. ``op.T``) to run
    plain BiCG instead, exercising the transpose SpMV the NapOperator
    front-end provides from the same compiled plan.
    """
    mv = spmv or a.matvec
    x = np.zeros_like(b)
    r = b - mv(x)
    b_norm = max(float(np.linalg.norm(b)), 1e-30)
    if spmv_t is not None:
        # plain BiCG (Lanczos biorthogonalisation) using A and A.T
        rt = r.copy()
        p, pt = r.copy(), rt.copy()
        rho = float(rt @ r)
        for it in range(1, maxiter + 1):
            ap = mv(p)
            alpha = _safe_div(rho, float(pt @ ap))
            x += alpha * p
            r -= alpha * ap
            rel = float(np.linalg.norm(r)) / b_norm
            if rel < tol:
                return x, it, rel
            rt = rt - alpha * spmv_t(pt)
            rho_new = float(rt @ r)
            beta = _safe_div(rho_new, rho)
            p = r + beta * p
            pt = rt + beta * pt
            rho = rho_new
        return x, maxiter, float(np.linalg.norm(r)) / b_norm
    rt0 = r.copy()
    rho = alpha = omega = 1.0
    v = p = np.zeros_like(b)
    for it in range(1, maxiter + 1):
        rho_new = float(rt0 @ r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        rho = rho_new
        p = r + beta * (p - omega * v)
        v = mv(p)
        alpha = _safe_div(rho, float(rt0 @ v))
        s = r - alpha * v
        t = mv(s)
        omega = _safe_div(float(t @ s), float(t @ t))
        x += alpha * p + omega * s
        r = s - omega * t
        rel = float(np.linalg.norm(r)) / b_norm
        if rel < tol:
            return x, it, rel
    return x, maxiter, float(np.linalg.norm(r)) / b_norm
