"""Vectorized CSR x CSR product (numpy; no scipy in the library path)."""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR, expand_positions

# Expansion budget: the pre-merge intermediate arrays (rows/cols/vals of
# every a_ik * B[k, :] product) are bounded to ~this many entries per
# chunk, so dense-ish A rows against wide B rows no longer allocate
# O(nnz(A) * max_row(B)) at once (~3 int64/float64 arrays, so the peak
# per-chunk footprint is ~24 B * DEFAULT_CHUNK_PRODUCTS ≈ 50 MB).
DEFAULT_CHUNK_PRODUCTS = 1 << 21


def _expand_merge(a: CSR, b: CSR, b_counts: np.ndarray, r0: int, r1: int):
    """Row-expand A rows [r0, r1) against B and merge duplicates.

    Products enumerate in A row-major order and merge via stable sort +
    ``reduceat`` — the same order/association for every chunk split, so
    chunking never changes a bit of the output.
    """
    lo, hi = a.indptr[r0], a.indptr[r1]
    ak, av = a.indices[lo:hi], a.data[lo:hi]
    ai = np.repeat(np.arange(r0, r1), np.diff(a.indptr[r0: r1 + 1]))
    counts = b_counts[ak]
    take = expand_positions(b.indptr[ak], counts)
    if take.size == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z.copy(), np.empty(0)
    rows = np.repeat(ai, counts)
    cols = b.indices[take]
    vals = np.repeat(av, counts) * b.data[take]
    key = rows * np.int64(b.shape[1]) + cols
    order = np.argsort(key, kind="stable")
    key, vals = key[order], vals[order]
    uniq, start = np.unique(key, return_index=True)
    return (uniq // b.shape[1], uniq % b.shape[1],
            np.add.reduceat(vals, start))


def csr_matmul(a: CSR, b: CSR,
               chunk_products: int = DEFAULT_CHUNK_PRODUCTS) -> CSR:
    """C = A @ B by row expansion: every nonzero (i, k) of A contributes
    a_ik * B[k, :]; duplicates are summed per (i, j).

    The expansion is CHUNKED over contiguous A-row blocks so the
    intermediate product arrays stay under ``chunk_products`` entries
    (one block may exceed it only when a single row does): peak memory
    is bounded instead of O(nnz(A) * max_row(B)).  Chunk boundaries fall
    on row boundaries and each (i, j) group merges in the same stable
    order, so the result is bit-for-bit independent of ``chunk_products``.
    """
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    shape = (a.shape[0], b.shape[1])
    b_counts = np.diff(b.indptr)
    # per-row expansion sizes -> cumulative products at each row boundary
    per_nnz = b_counts[a.indices]
    cum = np.concatenate([[0], np.cumsum(per_nnz)])[a.indptr]
    total = int(cum[-1])
    if total == 0:
        return CSR.from_coo(np.empty(0, np.int64), np.empty(0, np.int64),
                            np.empty(0), shape)
    parts = []
    r0 = 0
    n_rows = a.shape[0]
    while r0 < n_rows:
        r1 = int(np.searchsorted(cum, cum[r0] + chunk_products, side="right")) - 1
        r1 = min(max(r1, r0 + 1), n_rows)  # at least one row per chunk
        parts.append(_expand_merge(a, b, b_counts, r0, r1))
        r0 = r1
    rows = np.concatenate([p[0] for p in parts])
    cols = np.concatenate([p[1] for p in parts])
    vals = np.concatenate([p[2] for p in parts])
    # chunks are row-disjoint and ascending; each is already row-major
    return CSR.from_coo(rows, cols, vals, shape, sum_duplicates=False,
                        assume_sorted=True)
