"""Vectorized CSR x CSR product (numpy; no scipy in the library path)."""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR


def csr_matmul(a: CSR, b: CSR) -> CSR:
    """C = A @ B by row expansion: every nonzero (i, k) of A contributes
    a_ik * B[k, :]; duplicates are summed by CSR.from_coo."""
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    ai, ak, av = a.to_coo()
    if ai.size == 0:
        return CSR.from_coo(np.empty(0, np.int64), np.empty(0, np.int64),
                            np.empty(0), (a.shape[0], b.shape[1]))
    b_counts = np.diff(b.indptr)
    counts = b_counts[ak]
    total = int(counts.sum())
    if total == 0:
        return CSR.from_coo(np.empty(0, np.int64), np.empty(0, np.int64),
                            np.empty(0), (a.shape[0], b.shape[1]))
    ends = np.cumsum(counts)
    intra = np.arange(total) - np.repeat(ends - counts, counts)
    take = np.repeat(b.indptr[ak], counts) + intra
    rows = np.repeat(ai, counts)
    cols = b.indices[take]
    vals = np.repeat(av, counts) * b.data[take]
    return CSR.from_coo(rows, cols, vals, (a.shape[0], b.shape[1]))
