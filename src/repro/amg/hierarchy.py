"""Smoothed-aggregation AMG setup (strength -> aggregate -> tentative ->
smoothed P -> Galerkin RAP).

The paper's Figs. 8-10 measure SpMV communication on every level of AMG
hierarchies for a rotated-anisotropic diffusion and a linear-elasticity
problem; this module builds equivalent hierarchies so those experiments run
offline.  Coarse levels are small and *dense*, exactly the high-message-count
regime where NAPSpMV wins most (paper Sec. 5).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.amg.matmul import csr_matmul
from repro.sparse.csr import CSR


@dataclasses.dataclass
class Level:
    a: CSR
    p: Optional[CSR] = None       # prolongation to THIS level from coarse
    r: Optional[CSR] = None       # restriction (P^T)
    aggregates: Optional[np.ndarray] = None  # fine node -> aggregate id


def strength_graph(a: CSR, theta: float = 0.0) -> CSR:
    """Symmetric strength-of-connection: keep A_ij with
    |A_ij| >= theta * sqrt(|A_ii| |A_jj|); diagonal always kept."""
    rows, cols, vals = a.to_coo()
    diag = np.zeros(a.shape[0])
    dmask = rows == cols
    diag[rows[dmask]] = np.abs(vals[dmask])
    diag[diag == 0] = 1.0
    keep = np.abs(vals) >= theta * np.sqrt(diag[rows] * diag[cols])
    keep |= dmask
    return CSR.from_coo(rows[keep], cols[keep], vals[keep], a.shape,
                        sum_duplicates=False)


def standard_aggregation(s: CSR) -> np.ndarray:
    """Greedy two-pass aggregation on the strength graph.  Returns agg id
    per node (-1 never remains after pass 3)."""
    n = s.shape[0]
    agg = np.full(n, -1, dtype=np.int64)
    next_agg = 0
    # pass 1: nodes whose strong neighbourhood is fully unaggregated seed
    # a new aggregate containing that neighbourhood.
    for i in range(n):
        if agg[i] != -1:
            continue
        nbrs = s.indices[s.indptr[i]:s.indptr[i + 1]]
        if (agg[nbrs] == -1).all():
            agg[nbrs] = next_agg
            agg[i] = next_agg
            next_agg += 1
    # pass 2: attach stragglers to any aggregated strong neighbour.
    attach = agg.copy()
    for i in range(n):
        if agg[i] != -1:
            continue
        nbrs = s.indices[s.indptr[i]:s.indptr[i + 1]]
        hit = nbrs[agg[nbrs] != -1]
        if hit.size:
            attach[i] = agg[hit[0]]
    agg = attach
    # pass 3: remaining isolated nodes become singleton aggregates.
    for i in range(n):
        if agg[i] == -1:
            agg[i] = next_agg
            next_agg += 1
    return agg


def tentative_prolongator(agg: np.ndarray, nullspace: np.ndarray
                          ) -> tuple[CSR, np.ndarray]:
    """Local QR of the near-nullspace over each aggregate: P has one block
    column per (aggregate, nullspace vector); returns (P, coarse nullspace)."""
    n, nb = nullspace.shape
    n_agg = int(agg.max()) + 1
    rows_out, cols_out, vals_out = [], [], []
    bc = np.zeros((n_agg * nb, nb))
    order = np.argsort(agg, kind="stable")
    bounds = np.searchsorted(agg[order], np.arange(n_agg + 1))
    for a_id in range(n_agg):
        nodes = order[bounds[a_id]:bounds[a_id + 1]]
        blk = nullspace[nodes]                      # [sz, nb]
        q, r = np.linalg.qr(blk)
        if q.shape[1] < nb:  # aggregate smaller than the nullspace dim
            q = np.pad(q, ((0, 0), (0, nb - q.shape[1])))
            r = np.pad(r, ((0, nb - r.shape[0]), (0, 0)))
        rows_out.append(np.repeat(nodes, nb))
        cols_out.append(np.tile(a_id * nb + np.arange(nb), nodes.size))
        vals_out.append(q.reshape(-1))
        bc[a_id * nb:(a_id + 1) * nb] = r
    p = CSR.from_coo(np.concatenate(rows_out), np.concatenate(cols_out),
                     np.concatenate(vals_out), (n, n_agg * nb),
                     sum_duplicates=False)
    return p, bc


def _spectral_radius_dinv_a(a: CSR, iters: int = 15, seed: int = 0) -> float:
    diag = np.zeros(a.shape[0])
    rows, cols, vals = a.to_coo()
    m = rows == cols
    diag[rows[m]] = vals[m]
    diag[diag == 0] = 1.0
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(a.shape[0])
    lam = 1.0
    for _ in range(iters):
        y = a.matvec(x) / diag
        lam = float(np.linalg.norm(y) / max(np.linalg.norm(x), 1e-30))
        x = y / max(np.linalg.norm(y), 1e-30)
    return max(lam, 1e-12)


def smooth_prolongator(a: CSR, t: CSR, omega_scale: float = 4.0 / 3.0) -> CSR:
    """P = (I - omega D^-1 A) T with omega = omega_scale / rho(D^-1 A)."""
    omega = omega_scale / _spectral_radius_dinv_a(a)
    rows, cols, vals = a.to_coo()
    diag = np.zeros(a.shape[0])
    m = rows == cols
    diag[rows[m]] = vals[m]
    diag[diag == 0] = 1.0
    da = CSR.from_coo(rows, cols, -omega * vals / diag[rows], a.shape,
                      sum_duplicates=False)
    # add identity
    eye_rows = np.arange(a.shape[0])
    rows2 = np.concatenate([da.to_coo()[0], eye_rows])
    cols2 = np.concatenate([da.to_coo()[1], eye_rows])
    vals2 = np.concatenate([da.to_coo()[2], np.ones(a.shape[0])])
    s = CSR.from_coo(rows2, cols2, vals2, a.shape)
    return csr_matmul(s, t)


def smoothed_aggregation_hierarchy(a: CSR, nullspace: Optional[np.ndarray] = None,
                                   theta: float = 0.0, max_levels: int = 12,
                                   coarse_size: int = 64,
                                   rap=None) -> List[Level]:
    """Build the SA-AMG hierarchy; levels[0].a is the fine matrix.

    ``rap`` optionally overrides the Galerkin product: a callable
    ``rap(r, a, p) -> CSR`` assembling each coarse matrix.  The default
    is the host-side ``csr_matmul`` triple product; pass
    :func:`repro.spgemm.distributed_rap` to assemble EVERY coarse level
    through the node-aware distributed SpGEMM (the float64 simulate
    backend is bit-for-bit equal to the host product, so the hierarchy
    is identical — only the assembly path changes).
    """
    if nullspace is None:
        nullspace = np.ones((a.shape[0], 1))
    galerkin = rap or (lambda r_, a_, p_: csr_matmul(r_, csr_matmul(a_, p_)))
    levels = [Level(a=a)]
    b = nullspace
    while len(levels) < max_levels and levels[-1].a.shape[0] > coarse_size:
        a_l = levels[-1].a
        s = strength_graph(a_l, theta)
        agg = standard_aggregation(s)
        n_coarse_dofs = (int(agg.max()) + 1) * b.shape[1]
        if n_coarse_dofs >= 0.8 * a_l.shape[0]:  # coarsening stalled
            break
        t, bc = tentative_prolongator(agg, b)
        p = smooth_prolongator(a_l, t)
        r = p.transpose()
        a_c = galerkin(r, a_l, p)
        levels[-1].p = p
        levels[-1].r = r
        levels[-1].aggregates = agg
        levels.append(Level(a=a_c))
        b = bc
    return levels
