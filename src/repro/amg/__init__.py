from repro.amg.hierarchy import Level, smoothed_aggregation_hierarchy
from repro.amg.matmul import csr_matmul
from repro.amg.solve import (LevelOperators, amg_vcycle, bicgstab_solve,
                             cg_solve, level_operators)

__all__ = ["Level", "LevelOperators", "smoothed_aggregation_hierarchy",
           "csr_matmul", "amg_vcycle", "bicgstab_solve", "cg_solve",
           "level_operators"]
