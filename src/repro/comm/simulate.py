"""Float64 message-passing simulators for the multi-step strategy.

Mirror :func:`repro.core.spmv.simulate_nap_spmv` (and its transpose)
phase by phase, adding the fifth "direct" exchange that carries the
low-duplication columns owner -> requester in one hop.  The local
blocks, delivered values, and compute order are identical to the
single-step simulator's, so the forward result is bit-for-bit equal to
``simulate_nap_spmv`` on the same matrix — the strategies differ in
routing, never in arithmetic.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.comm.multistep import MultistepPlan
from repro.core.spmv import (_block_transpose_contrib, _gather_from,
                             _MailBox, _reverse_phase, split_all_blocks)
from repro.sparse.csr import CSR


def simulate_multistep_spmv(a: CSR, v: np.ndarray, plan: MultistepPlan,
                            wire=None) -> np.ndarray:
    """w = A v through the five-phase multi-step exchange (numpy).

    ``v`` is owned by the plan's column partition, the output by the row
    partition.  ``wire`` optionally threads a
    :class:`repro.core.integrity.SimWire` through all five mailboxes.
    """
    nap, direct = plan.nap, plan.direct
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    w = np.zeros(a.shape[0])

    owned = [{int(j): float(v[j]) for j in cpart.rows_of(r)}
             for r in range(topo.n_procs)]

    # -- phase A: fully-local exchange (on_node -> on_node) ------------------
    box_full = _MailBox(wire, "full")
    for r in range(topo.n_procs):
        for msg in nap.local_full_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "full-local must stay on node"
            box_full.post(msg, _gather_from(owned[r], msg.idx))

    # -- phase B: local init redistribution (on_node -> off_node) ------------
    box_init = _MailBox(wire, "init")
    for r in range(topo.n_procs):
        for msg in nap.local_init_sends[r]:
            assert topo.same_node(msg.src, msg.dst), "init redistribution stays on node"
            box_init.post(msg, _gather_from(owned[r], msg.idx))
    staged = [dict(owned[r]) for r in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in nap.local_init_recvs[r]:
            for jj, val in zip(msg.idx, box_init.fetch(msg)):
                staged[r][int(jj)] = float(val)

    # -- phase C: aggregated inter-node exchange (high-duplication share) ----
    box_inter = _MailBox(wire, "inter")
    for r in range(topo.n_procs):
        for msg in nap.inter_sends[r]:
            assert not topo.same_node(msg.src, msg.dst), "inter phase crosses nodes"
            box_inter.post(msg, _gather_from(staged[r], msg.idx))
    arrived: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    for r in range(topo.n_procs):
        for msg in nap.inter_recvs[r]:
            for jj, val in zip(msg.idx, box_inter.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- phase D: local final scatter (off_node -> on_node) ------------------
    box_final = _MailBox(wire, "final")
    for r in range(topo.n_procs):
        for msg in nap.local_final_sends[r]:
            assert topo.same_node(msg.src, msg.dst)
            box_final.post(msg, _gather_from(arrived[r], msg.idx))
    for r in range(topo.n_procs):
        for msg in nap.local_final_recvs[r]:
            for jj, val in zip(msg.idx, box_final.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- phase E: direct owner -> requester exchange (low duplication) -------
    box_direct = _MailBox(wire, "direct")
    for r in range(topo.n_procs):
        for msg in direct.sends[r]:
            assert not topo.same_node(msg.src, msg.dst), \
                "direct phase carries only off-node traffic"
            box_direct.post(msg, _gather_from(owned[r], msg.idx))
    for r in range(topo.n_procs):
        for msg in direct.recvs[r]:
            for jj, val in zip(msg.idx, box_direct.fetch(msg)):
                arrived[r][int(jj)] = float(val)

    # -- compute: identical to the single-step simulator ---------------------
    for r in range(topo.n_procs):
        blk = blocks[r]
        w_local = blk.on_proc.matvec(
            np.array([owned[r][int(j)] for j in blk.x_rows])
            if blk.x_rows.size else np.zeros(0))
        if blk.on_node_cols.size:
            b_ll: Dict[int, float] = {}
            for msg in nap.local_full_recvs[r]:
                for jj, val in zip(msg.idx, box_full.fetch(msg)):
                    b_ll[int(jj)] = float(val)
            w_local = w_local + blk.on_node.matvec(
                _gather_from(b_ll, blk.on_node_cols))
        if blk.off_node_cols.size:
            w_local = w_local + blk.off_node.matvec(
                _gather_from(arrived[r], blk.off_node_cols))
        w[blk.rows] = w_local
    return w


def simulate_multistep_spmv_transpose(a: CSR, u: np.ndarray,
                                      plan: MultistepPlan) -> np.ndarray:
    """z = A.T u through the reversed five-phase exchange.

    Reverse order: final scatter, inter-node aggregate, then the direct
    contributions go straight back to their owners, then init, then the
    fully-local phase — the exact mirror of the forward routing.
    """
    nap, direct = plan.nap, plan.direct
    part, topo = plan.partition, plan.topology
    cpart = plan.col_part
    blocks = split_all_blocks(a, part, topo, col_part=cpart)
    z = np.zeros(a.shape[1])
    pending: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    node_pending: List[Dict[int, float]] = [dict() for _ in range(topo.n_procs)]
    for r in range(topo.n_procs):
        blk = blocks[r]
        z_own, c_node, c_off = _block_transpose_contrib(blk, u)
        z[blk.x_rows] += z_own[: blk.x_rows.size]
        for j, val in zip(blk.on_node_cols, c_node[: blk.on_node_cols.size]):
            node_pending[r][int(j)] = float(val)
        for j, val in zip(blk.off_node_cols, c_off[: blk.off_node_cols.size]):
            pending[r][int(j)] = float(val)

    def accumulate(rank: int, j: int, val: float) -> None:
        pending[rank][j] = pending[rank].get(j, 0.0) + val

    def to_owner(rank: int, j: int, val: float) -> None:
        assert cpart.owner[j] == rank, "reversed message missed the owner"
        z[j] += val

    # -- reverse final: consumers return contributions to the home rank -----
    _reverse_phase(nap.local_final_sends, pending, accumulate)
    # -- reverse inter: home ranks return aggregates across the network ------
    _reverse_phase(nap.inter_sends, pending, accumulate)
    # -- reverse direct: requesters return contributions straight to owners --
    _reverse_phase(direct.sends, pending, to_owner)
    # -- reverse init: staging ranks return contributions to the owners ------
    _reverse_phase(nap.local_init_sends, pending, to_owner)
    # whatever remains was staged from the rank's own values: fold into z.
    for r in range(topo.n_procs):
        for j, val in pending[r].items():
            assert cpart.owner[j] == r, "unrouted transpose contribution"
            z[j] += val

    # -- reverse full: on-node consumers return directly to the owners -------
    _reverse_phase(nap.local_full_sends, node_pending, to_owner)
    assert all(not p for p in node_pending), "unrouted on-node contributions"
    return z
