"""Multi-step node-aware exchange: split off-node traffic by duplication.

The paper's single aggregated inter-node exchange (``NAPPlan``) wins by
deduplicating columns that several processes on the destination node
need: each such column crosses the network once and fans out locally.
Its follow-up (arXiv:1904.05838, PAPERS.md) observes the flip side —
columns needed by only one (or few) processes on the destination node
gain nothing from the dedup, yet still pay the init/final intra-node
hops and, in the padded SPMD lowering, inflate the aggregated
exchange's slot pad: one process's dense rows set the pad every other
message in the all_to_all must stretch to.

``build_multistep_plan`` therefore splits the deduped off-process
triples ``(t, r, j)`` by a duplication threshold:

* ``d(j) >= threshold`` — the column is needed by enough processes on
  the destination node that the node-aware dedup pays; it goes through
  an ordinary :class:`NAPPlan` (full/init/inter/final), built over its
  share of the triples.
* ``d(j) < threshold`` — low duplication ("dense rows go direct"): the
  column is shipped owner -> requester in one network hop through a
  :class:`StandardPlan` sub-exchange (the "direct" phase), bypassing
  the aggregation entirely.

On-node triples always ride the NAP sub-plan's full phase.  With
``threshold <= 1`` nothing goes direct and the plan degenerates to the
single-step NAP plan over the same triples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.comm_graph import (NAPPlan, PhaseStats, StandardPlan,
                                   _offproc_pairs, build_nap_plan,
                                   build_standard_plan, nap_stats)
from repro.core.partition import RowPartition
from repro.core.topology import Topology

#: ``threshold="auto"``: dedup pays as soon as a second process on the
#: destination node needs the column (one saved network crossing).
AUTO_THRESHOLD = 2


def resolve_threshold(threshold: Union[int, str], topo: Topology) -> int:
    if threshold == "auto":
        return AUTO_THRESHOLD
    thr = int(threshold)
    if thr < 1:
        raise ValueError(f"duplication threshold must be >= 1, got {thr}")
    return thr


def duplication_counts(t: np.ndarray, j: np.ndarray, topo: Topology,
                       n_cols: int) -> np.ndarray:
    """Per-triple duplication: how many distinct processes on the triple's
    destination NODE request column j.  Triples are deduped per
    ``(t, r, j)`` and a column has one owner, so the count of triples
    sharing ``(node_of(t), j)`` IS the number of requesting processes."""
    if t.size == 0:
        return np.zeros(0, dtype=np.int64)
    tn = topo.node_of_array(t).astype(np.int64)
    key = tn * np.int64(n_cols) + j
    _, inv, counts = np.unique(key, return_inverse=True, return_counts=True)
    return counts[inv]


@dataclasses.dataclass
class MultistepPlan:
    """A NAP sub-plan for the high-duplication share plus a direct
    (standard-style, owner -> requester) sub-plan for the rest.

    Both sub-plans are built over the SAME topology/partitions; their
    triple sets partition the full off-process set, so the union of
    delivered columns equals what a single-step plan delivers.
    """

    topology: Topology
    partition: RowPartition
    nap: NAPPlan
    direct: StandardPlan
    threshold: int
    col_partition: Optional[RowPartition] = None

    @property
    def col_part(self) -> RowPartition:
        return self.col_partition if self.col_partition is not None \
            else self.partition


def build_multistep_plan(indptr: np.ndarray, indices: np.ndarray,
                         part: RowPartition, topo: Topology,
                         pairing: str = "balanced",
                         col_part: Optional[RowPartition] = None,
                         threshold: Union[int, str] = "auto",
                         pairs: Optional[Tuple[np.ndarray, np.ndarray,
                                               np.ndarray]] = None
                         ) -> MultistepPlan:
    """Split the off-process triples by duplication and build both
    sub-plans.  ``pairs`` optionally supplies precomputed triples (same
    contract as :func:`build_nap_plan`)."""
    thr = resolve_threshold(threshold, topo)
    cpart = part if col_part is None else col_part
    t, r, j = pairs if pairs is not None else \
        _offproc_pairs(indptr, indices, part, cpart)
    tn = topo.node_of_array(t)
    rn = topo.node_of_array(r)
    off_node = tn != rn
    d = duplication_counts(t[off_node], j[off_node], topo, cpart.n_rows)
    direct_mask = np.zeros(t.shape, dtype=bool)
    direct_mask[np.flatnonzero(off_node)[d < thr]] = True
    nap_sub = build_nap_plan(indptr, indices, part, topo, pairing=pairing,
                             col_part=col_part,
                             pairs=(t[~direct_mask], r[~direct_mask],
                                    j[~direct_mask]))
    direct_sub = build_standard_plan(indptr, indices, part, topo,
                                     col_part=col_part,
                                     pairs=(t[direct_mask], r[direct_mask],
                                            j[direct_mask]))
    return MultistepPlan(topology=topo, partition=part, nap=nap_sub,
                         direct=direct_sub, threshold=thr,
                         col_partition=col_part)


def multistep_stats(plan: MultistepPlan,
                    bytes_per_val: int = 8) -> Dict[str, PhaseStats]:
    """NAP phase stats plus the direct exchange (every direct message
    crosses the network by construction)."""
    out = nap_stats(plan.nap, bytes_per_val)
    out["direct"] = PhaseStats.of(plan.direct.sends, bytes_per_val)
    return out
