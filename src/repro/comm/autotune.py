"""Per-operator (and per-direction) comm-strategy selection.

``choose_comm`` builds all three plans once from the matrix structure,
scores each with the slot-granular :func:`repro.comm.cost.planned_traffic`
model plus the postal alpha-beta term, and picks the winner
lexicographically:

1. fewest modeled injected inter-node bytes (padded slots + integrity
   side-channel) — the quantity the paper optimizes;
2. then lowest postal total time (start-ups matter when bytes tie);
3. then strategy preference ``nap < multistep < standard`` — the
   incumbent wins exact ties, so e.g. a multistep plan whose direct
   share is empty (it degenerates to the same exchange) never displaces
   plain nap.

The verdict dict is JSON-serializable and is merged into
``autotune_report()`` by the operator front-end, mirroring the local
format autotuner's reporting.
"""
from __future__ import annotations

from typing import Dict, Optional, Union

import numpy as np

from repro.core.cost_model import (PostalParams, TPU_V5E_POSTAL,
                                   postal_comm_time)
from repro.comm.cost import planned_traffic
from repro.comm.strategies import COMM_STRATEGIES

#: tie-break order: prefer the paper's strategy, then its refinement.
PREFERENCE = ("nap", "multistep", "standard")


def build_candidate_plans(indptr: np.ndarray, indices: np.ndarray, part,
                          topo, pairing: str = "balanced", col_part=None,
                          threshold: Union[int, str] = "auto") -> Dict:
    """One plan per registered strategy, built from the same structure."""
    return {
        name: strat.build_plan(indptr, indices, part, topo, pairing=pairing,
                               col_part=col_part, threshold=threshold)
        for name, strat in COMM_STRATEGIES.items()
    }


def comm_verdict(plans: Dict, direction: str = "forward",
                 bytes_per_val: int = 4, nv: int = 1,
                 integrity: str = "off",
                 params: PostalParams = TPU_V5E_POSTAL,
                 wire_dtype: str = "f32") -> Dict:
    """Score prebuilt candidate plans for one exchange direction.

    ``wire_dtype`` scores the quantized payload width (see
    :func:`repro.comm.cost.planned_traffic`) — a narrower wire shrinks
    every candidate's modeled bytes by the same factor, but the postal
    alpha term does not shrink, so the verdict can flip toward
    message-frugal strategies as payloads thin out.
    """
    candidates: Dict[str, Dict] = {}
    for name, plan in plans.items():
        traffic = planned_traffic(plan, bytes_per_val=bytes_per_val, nv=nv,
                                  direction=direction, integrity=integrity,
                                  wire_dtype=wire_dtype)
        times = postal_comm_time(traffic, params)
        candidates[name] = {
            "injected_inter_bytes": traffic["injected_inter_bytes"],
            "effective_inter_bytes": traffic["effective_inter_bytes"],
            "injected_intra_bytes": traffic["injected_intra_bytes"],
            "postal_time_s": times["total"],
            "postal_phase_s": {k: v for k, v in times.items()
                               if k != "total"},
        }
    chosen = min(
        candidates,
        key=lambda n: (candidates[n]["injected_inter_bytes"],
                       candidates[n]["postal_time_s"],
                       PREFERENCE.index(n)))
    return {
        "chosen": chosen,
        "direction": direction,
        "wire_dtype": wire_dtype,
        "postal_params": params.name,
        "candidates": candidates,
    }


def choose_comm(indptr: np.ndarray, indices: np.ndarray, part, topo,
                pairing: str = "balanced", col_part=None,
                threshold: Union[int, str] = "auto",
                bytes_per_val: int = 4, nv: int = 1,
                integrity: str = "off",
                params: PostalParams = TPU_V5E_POSTAL,
                plans: Optional[Dict] = None,
                wire_dtype: str = "f32") -> Dict:
    """Full per-direction verdict for one operator's structure.

    Returns ``{"forward": verdict, "transpose": verdict, "threshold"}``;
    forward and transpose can disagree because the per-rank bottleneck
    flips roles when every message reverses.  Pass ``plans`` to reuse
    candidate plans the caller already built.
    """
    if plans is None:
        plans = build_candidate_plans(indptr, indices, part, topo,
                                      pairing=pairing, col_part=col_part,
                                      threshold=threshold)
    fwd = comm_verdict(plans, direction="forward", bytes_per_val=bytes_per_val,
                       nv=nv, integrity=integrity, params=params,
                       wire_dtype=wire_dtype)
    bwd = comm_verdict(plans, direction="transpose",
                       bytes_per_val=bytes_per_val, nv=nv,
                       integrity=integrity, params=params,
                       wire_dtype=wire_dtype)
    ms = plans.get("multistep")
    return {
        "forward": fwd,
        "transpose": bwd,
        "threshold": getattr(ms, "threshold", None),
        "plans": plans,
    }
