"""Registry of pluggable exchange strategies.

A :class:`CommStrategy` names one way to route the off-process columns
of a distributed SpMV: the flat ``standard`` all_to_all, the paper's
aggregated node-aware ``nap`` exchange, or the duplication-split
``multistep`` variant.  Every strategy exposes the same
``build_plan(indptr, indices, part, topo, ...)`` entry point so the
executors and the autotuner can treat them uniformly; ``"auto"`` is not
a strategy but an instruction to let :func:`repro.comm.autotune.choose_comm`
pick one per operator (and per direction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.comm_graph import build_nap_plan, build_standard_plan
from repro.core.integrity import message_phases
from repro.comm.multistep import build_multistep_plan


def _build_standard(indptr, indices, part, topo, pairing="balanced",
                    col_part=None, threshold="auto"):
    del pairing, threshold  # one flat exchange: nothing to pair or split
    return build_standard_plan(indptr, indices, part, topo, col_part=col_part)


def _build_nap(indptr, indices, part, topo, pairing="balanced",
               col_part=None, threshold="auto"):
    del threshold
    return build_nap_plan(indptr, indices, part, topo, pairing=pairing,
                          col_part=col_part)


def _build_multistep(indptr, indices, part, topo, pairing="balanced",
                     col_part=None, threshold="auto"):
    return build_multistep_plan(indptr, indices, part, topo, pairing=pairing,
                                col_part=col_part, threshold=threshold)


@dataclasses.dataclass(frozen=True)
class CommStrategy:
    """One exchange strategy: its executor method name, message phases
    (in program order, matching ``repro.core.integrity``), and plan
    builder."""

    name: str
    method: str
    phases: Tuple[str, ...]
    build_plan: Callable
    description: str


COMM_STRATEGIES: Dict[str, CommStrategy] = {
    "standard": CommStrategy(
        name="standard", method="standard",
        phases=message_phases("standard"),
        build_plan=_build_standard,
        description="one flat all_to_all over every (proc, proc) pair"),
    "nap": CommStrategy(
        name="nap", method="nap",
        phases=message_phases("nap"),
        build_plan=_build_nap,
        description="aggregated node-aware exchange "
                    "(intra init -> one inter all_to_all -> intra final)"),
    "multistep": CommStrategy(
        name="multistep", method="multistep",
        phases=message_phases("multistep"),
        build_plan=_build_multistep,
        description="node-aware exchange for high-duplication columns, "
                    "direct owner->requester hop for the rest"),
}

#: what ``operator(comm=...)`` accepts; "auto" resolves via the autotuner.
COMM_CHOICES: Tuple[str, ...] = ("standard", "nap", "multistep", "auto")


def get_strategy(name: str) -> CommStrategy:
    try:
        return COMM_STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown comm strategy {name!r}; "
            f"expected one of {sorted(COMM_STRATEGIES)}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(COMM_STRATEGIES)
