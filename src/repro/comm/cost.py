"""Slot-granular planned traffic for the comm-strategy chooser.

The SPMD lowerings (``repro.core.spmv_jax``) pad every message in an
exchange phase to that phase's largest message, so the bytes a strategy
*injects* differ from the bytes it *needs* to move.  This module costs a
plan the way the lowering will actually run it: per phase, each existing
(src, dst) message is charged the phase pad; absent slots cost nothing
(MPI-style — an all_to_all slot nobody fills is not a message here, the
full-buffer view lives in ``padded_traffic`` on the compiled program).

The resulting payload is what :func:`repro.core.cost_model.postal_comm_time`
consumes and what the ``comm_autotune`` benchmark block quotes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.comm_graph import NAPPlan, StandardPlan
from repro.comm.multistep import MultistepPlan

#: bytes of the per-slot u32 checksum side-channel (PR 7) per message slot.
_CHECKSUM_BYTES_PER_SLOT = 4


def _phase_entry(send_lists: Sequence[List], recv_lists: Sequence[List],
                 pad: int, inter: bool, bytes_per_val: int, nv: int,
                 direction: str, n_slots: int, integrity: str) -> Dict:
    """Account one exchange phase.

    ``pad`` is the phase's slot size in values (max message length,
    matching the compiled program).  ``direction`` picks whose buffers
    set the per-rank maxima: the forward program sends along
    ``send_lists``; the transpose program reverses every message, so the
    forward *receiver* becomes the bottleneck sender.  Totals are
    direction-independent.
    """
    rank_lists = send_lists if direction == "forward" else recv_lists
    bpv = bytes_per_val * nv
    n_msgs = sum(len(msgs) for msgs in send_lists)
    effective = sum(m.size for msgs in send_lists for m in msgs) * bpv
    padded = n_msgs * pad * bpv
    max_rank_msgs = max((len(msgs) for msgs in rank_lists), default=0)
    max_rank_padded = max((len(msgs) * pad * bpv for msgs in rank_lists),
                          default=0)
    # PR 7's integrity side-channel: a second tiny exchange shipping one
    # u32 per slot per rank, regardless of how many slots carry data.
    checksum = n_slots * _CHECKSUM_BYTES_PER_SLOT if integrity != "off" \
        and n_msgs > 0 else 0
    return {
        "n_msgs": int(n_msgs),
        "pad": int(pad),
        "effective_bytes": int(effective),
        "padded_bytes": int(padded),
        "max_rank_msgs": int(max_rank_msgs),
        "max_rank_padded_bytes": int(max_rank_padded),
        "checksum_bytes": int(checksum),
        "inter": bool(inter),
    }


def _pad_of(send_lists: Sequence[List]) -> int:
    return max((m.size for msgs in send_lists for m in msgs), default=1) or 1


def _split_pair(plan: StandardPlan):
    """Split the flat pair exchange into inter/intra message lists while
    keeping the SHARED pad the compiled program uses for both."""
    topo = plan.topology
    n = topo.n_procs
    s_inter: List[List] = [[] for _ in range(n)]
    s_intra: List[List] = [[] for _ in range(n)]
    r_inter: List[List] = [[] for _ in range(n)]
    r_intra: List[List] = [[] for _ in range(n)]
    for r in range(n):
        for m in plan.sends[r]:
            (s_intra if topo.same_node(m.src, m.dst) else s_inter)[r].append(m)
        for m in plan.recvs[r]:
            (r_intra if topo.same_node(m.src, m.dst) else r_inter)[r].append(m)
    return s_inter, s_intra, r_inter, r_intra


def planned_traffic(plan, bytes_per_val: int = 4, nv: int = 1,
                    direction: str = "forward",
                    integrity: str = "off",
                    wire_dtype: str = "f32") -> Dict:
    """Phase-by-phase injected traffic for a Standard/NAP/Multistep plan.

    Returns ``{"strategy", "direction", "phases": {name: entry},
    "injected_inter_bytes", "effective_inter_bytes",
    "injected_intra_bytes", "effective_intra_bytes"}`` where each phase
    entry carries padded/effective totals, per-rank maxima for the
    requested direction, the integrity side-channel bytes, and an
    ``inter`` flag.

    ``wire_dtype`` (``"f32"`` | ``"bf16"`` | ``"fp8_e4m3"``) charges the
    quantized payload width of :mod:`repro.moe.wire` instead of
    ``bytes_per_val`` — halved/quartered wire bytes feed the comm
    verdict the same way the NAP dedup does.  The integrity
    side-channel stays one u32 per slot regardless: checksums are
    computed OVER the quantized words, not widened by them.
    """
    if direction not in ("forward", "transpose"):
        raise ValueError(f"unknown direction {direction!r}")
    if wire_dtype != "f32":
        from repro.moe.wire import wire_bytes
        bytes_per_val = wire_bytes(wire_dtype)
    topo = plan.topology
    phases: Dict[str, Dict] = {}

    def nap_phases(nap: NAPPlan) -> None:
        pads = {
            "full": _pad_of(nap.local_full_sends),
            "init": _pad_of(nap.local_init_sends),
            "inter": _pad_of(nap.inter_sends),
            "final": _pad_of(nap.local_final_sends),
        }
        phases["full"] = _phase_entry(
            nap.local_full_sends, nap.local_full_recvs, pads["full"], False,
            bytes_per_val, nv, direction, topo.ppn, integrity)
        phases["init"] = _phase_entry(
            nap.local_init_sends, nap.local_init_recvs, pads["init"], False,
            bytes_per_val, nv, direction, topo.ppn, integrity)
        phases["inter"] = _phase_entry(
            nap.inter_sends, nap.inter_recvs, pads["inter"], True,
            bytes_per_val, nv, direction, topo.n_nodes, integrity)
        phases["final"] = _phase_entry(
            nap.local_final_sends, nap.local_final_recvs, pads["final"],
            False, bytes_per_val, nv, direction, topo.ppn, integrity)

    if isinstance(plan, MultistepPlan):
        strategy = "multistep"
        nap_phases(plan.nap)
        phases["direct"] = _phase_entry(
            plan.direct.sends, plan.direct.recvs, _pad_of(plan.direct.sends),
            True, bytes_per_val, nv, direction, topo.n_procs, integrity)
    elif isinstance(plan, NAPPlan):
        strategy = "nap"
        nap_phases(plan)
    elif isinstance(plan, StandardPlan):
        strategy = "standard"
        s_inter, s_intra, r_inter, r_intra = _split_pair(plan)
        pad = _pad_of(plan.sends)  # shared across the flat exchange
        phases["pair_inter"] = _phase_entry(
            s_inter, r_inter, pad, True, bytes_per_val, nv, direction,
            topo.n_procs, integrity)
        phases["pair_intra"] = _phase_entry(
            s_intra, r_intra, pad, False, bytes_per_val, nv, direction,
            topo.n_procs, integrity)
    else:
        raise TypeError(f"unsupported plan type {type(plan).__name__}")

    def total(key: str, inter: bool) -> int:
        return sum(ph[key] for ph in phases.values() if ph["inter"] is inter)

    return {
        "strategy": strategy,
        "direction": direction,
        "wire_dtype": wire_dtype,
        "bytes_per_val": int(bytes_per_val),
        "phases": phases,
        "injected_inter_bytes": total("padded_bytes", True)
        + total("checksum_bytes", True),
        "effective_inter_bytes": total("effective_bytes", True),
        "injected_intra_bytes": total("padded_bytes", False)
        + total("checksum_bytes", False),
        "effective_intra_bytes": total("effective_bytes", False),
    }
