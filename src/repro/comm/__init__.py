"""Comm-strategy subsystem: pluggable exchange schedules for the
distributed SpMV operator stack.  See README.md in this directory."""
from repro.comm.autotune import (PREFERENCE, build_candidate_plans,
                                 choose_comm, comm_verdict)
from repro.comm.cost import planned_traffic
from repro.comm.multistep import (AUTO_THRESHOLD, MultistepPlan,
                                  build_multistep_plan, duplication_counts,
                                  multistep_stats, resolve_threshold)
from repro.comm.simulate import (simulate_multistep_spmv,
                                 simulate_multistep_spmv_transpose)
from repro.comm.strategies import (COMM_CHOICES, COMM_STRATEGIES,
                                   CommStrategy, available_strategies,
                                   get_strategy)

__all__ = [
    "AUTO_THRESHOLD",
    "COMM_CHOICES",
    "COMM_STRATEGIES",
    "CommStrategy",
    "MultistepPlan",
    "PREFERENCE",
    "available_strategies",
    "build_candidate_plans",
    "build_multistep_plan",
    "choose_comm",
    "comm_verdict",
    "duplication_counts",
    "get_strategy",
    "multistep_stats",
    "planned_traffic",
    "resolve_threshold",
    "simulate_multistep_spmv",
    "simulate_multistep_spmv_transpose",
]
