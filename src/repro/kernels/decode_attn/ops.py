"""User-facing decode attention: flat-head layout + cache padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention_grouped


def decode_attention(q, k_cache, v_cache, lengths, *, softcap: float = 0.0,
                     block_s: int = 512, interpret: bool = True) -> jax.Array:
    """GQA decode attention.

    q:        [B, H, D]   one new token per sequence
    k_cache:  [B, S, Hkv, D]
    v_cache:  [B, S, Hkv, D]
    lengths:  [B] int32 valid prefix per sequence
    returns   [B, H, D]
    """
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, g, D)
    kt = jnp.swapaxes(k_cache, 1, 2)      # [B, Hkv, S, D]
    vt = jnp.swapaxes(v_cache, 1, 2)
    pad = (-S) % block_s
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = decode_attention_grouped(qg.astype(jnp.float32),
                                   kt.astype(jnp.float32),
                                   vt.astype(jnp.float32),
                                   lengths.astype(jnp.int32),
                                   scale=scale, softcap=softcap,
                                   block_s=block_s, interpret=interpret)
    return out.reshape(B, H, D)
