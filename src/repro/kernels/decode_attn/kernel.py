"""Pallas TPU kernel: single-token GQA decode attention (flash-decode).

One new query token attends over a long KV cache.  The cache is streamed
through VMEM in ``block_s``-sized chunks with an online-softmax running
(max, sum, acc) carried in VMEM scratch across the sequential s-grid axis;
per-sequence valid lengths are scalar-prefetched so padding slots beyond
the cache fill never contribute.

Grid: (batch, kv_heads, S/block_s) — batch and head axes are parallel, the
sequence axis is the sequential accumulation axis.

VMEM per step (f32): q (g, D) + k/v (block_s, D) x2 + acc (g, D): with
g = 16 query heads/group, D = 128, block_s = 512 this is ~0.6 MiB, double
buffered — the DMA of chunk s+1 overlaps the matmuls of chunk s.

Supports the gemma2 logit soft-cap (scores = cap * tanh(scores / cap)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_BIG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, softcap: float, block_s: int):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                      # [g, D]
    k = k_ref[0, 0]                      # [block_s, D]
    v = v_ref[0, 0]                      # [block_s, D]
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = pos < len_ref[b]
    scores = jnp.where(mask, scores, NEG_BIG)

    m_prev = m_ref[...]                  # [g, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(scores - m_new), 0.0)      # [g, block_s]
    alpha = jnp.exp(m_prev - m_new)                        # [g, 1]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "block_s", "interpret"))
def decode_attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                             lengths: jax.Array, *, scale: float,
                             softcap: float = 0.0, block_s: int = 512,
                             interpret: bool = True) -> jax.Array:
    """q [B, Hkv, g, D]; k, v [B, Hkv, S, D]; lengths [B] -> out [B, Hkv, g, D]."""
    B, Hkv, g, D = q.shape
    S = k.shape[2]
    assert S % block_s == 0, (S, block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, S // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s, lens: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s, lens: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, s, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, D), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             block_s=block_s)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, q, k, v)
