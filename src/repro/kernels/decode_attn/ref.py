"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, *, scale, softcap: float = 0.0):
    """q [B, Hkv, g, D]; k, v [B, Hkv, S, D]; lengths [B] -> [B, Hkv, g, D]."""
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k).astype(jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    S = k.shape[2]
    mask = jnp.arange(S)[None, :] < lengths[:, None]       # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v).astype(jnp.float32)
