"""Pure-jnp oracle for the packed ELL SpMV/SpMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ell_spmm_packed_ref(cols, vals, xs) -> jnp.ndarray:
    """Same contract as :func:`kernel.ell_spmm_packed` (gather + reduce)."""
    x = jnp.concatenate([jnp.asarray(x, jnp.float32) for x in xs], axis=0)
    gathered = x[jnp.maximum(cols, 0)]                   # [n_rows, kmax, nv]
    valid = (cols >= 0)[..., None]
    return (vals[..., None] * jnp.where(valid, gathered, 0.0)).sum(axis=1)


def ell_spmv_ref(ell, v):
    """Oracle on a sparse.ELL container + element vector (numpy/jnp)."""
    out = ell_spmm_packed_ref(jnp.asarray(ell.cols), jnp.asarray(ell.vals),
                              (jnp.asarray(v).reshape(-1, 1),))
    return out.reshape(-1)
