"""Pallas TPU kernel: ELL (padded-row) SpMV / SpMM over a packed x operand.

This is the block-hostile branch of the adaptive local-compute engine
(`core/spmv_jax.py`): where the fused BSR path would densify (bm, bn)
tiles at low block fill, the ELL path keeps the matrix as two
[n_rows, kmax] arrays (column ids + values) and gathers x *rows* inside
the kernel on the VPU — no MXU tiles, no scatter, padding overhead
bounded by kmax / mean-row-length.

Zero-copy packed x: the NAPSpMV's three buffers (``v_loc``, on-node recv,
off-node recv) are passed as SEPARATE refs — the executor never
materialises the concatenated operand in HBM.  Column ids are emitted in
the packed domain ``[0, len(v) | len(v)+len(bnode) | ...)`` at plan-compile
time, and the kernel concatenates the segment blocks in VMEM (a register/
VMEM move, not an HBM round-trip) before one vectorised gather.  Ordering
the segments on-process -> on-node -> off-node keeps the streaming
convention of the fused BSR kernel.

Grid: (n_rows / rows_block, nv / nv_block), both parallel; each step is
one fused gather + multiply + k-axis reduction, so interpret-mode grid
overhead stays tiny (the BSR path's slot axis is gone).

VMEM per grid step (f32):

    rows_block * kmax * 8        cols + vals tile
  + n_x * nv_block * 4           the whole packed x, one nv tile
  + rows_block * kmax * nv_block * 4   gather temporary
  + rows_block * nv_block * 4    output tile

``_pick_rows_block`` shrinks rows_block until this fits the budget; the
format autotuner (`core/cost_model.py`) refuses ELL outright when the
packed x alone cannot fit, falling back to COO.

Padding slots (col == -1, val == 0) clamp to x row 0 and multiply by
zero, so they are mathematically inert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

# Per-step working-set allowance used when auto-picking rows_block; well
# under the ~16 MiB of VMEM to leave room for double buffering.
_VMEM_STEP_BUDGET = 6 * 2**20


def _pick_rows_block(n_rows: int, kmax: int, n_x: int, nv_block: int) -> int:
    """Largest row tile from {n_rows, 128, 8} dividing n_rows that keeps the
    per-step working set under the VMEM budget (8 always divides: packed
    row counts are padded to the BSR lane multiple upstream)."""
    for rb in (n_rows, 128, 8):
        if rb > n_rows or n_rows % rb:
            continue
        step = (rb * kmax * 8 + n_x * nv_block * 4
                + rb * kmax * nv_block * 4 + rb * nv_block * 4)
        if step <= _VMEM_STEP_BUDGET:
            return rb
    return 8


def _ell_kernel(cols_ref, vals_ref, *rest):
    *x_refs, o_ref = rest
    x = x_refs[0][...]
    if len(x_refs) > 1:  # VMEM concat of the packed segments — no HBM copy
        x = jnp.concatenate([x] + [r[...] for r in x_refs[1:]], axis=0)
    cols = cols_ref[...]                                   # [rb, kmax]
    gathered = jnp.take(x, jnp.maximum(cols, 0).reshape(-1), axis=0,
                        ).reshape(cols.shape + (x.shape[-1],))
    o_ref[...] = (vals_ref[...][..., None] * gathered).sum(axis=1)


@functools.partial(jax.jit,
                   static_argnames=("nv_block", "rows_block", "interpret"))
def ell_spmm_packed(cols: jax.Array, vals: jax.Array, xs, *,
                    nv_block: int = 128, rows_block: int = 0,
                    interpret: bool = True) -> jax.Array:
    """w = A @ concat(xs) for the ELL layout, without materialising the concat.

    cols: [n_rows, kmax] int32 column ids in the packed x domain (-1 = pad)
    vals: [n_rows, kmax] float32 (0 on padding slots)
    xs:   tuple of [len_i, nv] segments; the packed domain is their
          concatenation in order (e.g. (v_loc, b_on_node, b_off_node))
    returns [n_rows, nv] float32

    Grid: (n_rows / rows_block, nv / nv_block), both parallel.  nv is
    padded up to a multiple of nv_block and sliced back.
    """
    xs = tuple(jnp.asarray(x, jnp.float32) for x in xs)
    n_rows, kmax = cols.shape
    nv = xs[0].shape[-1]
    nv_block = min(nv_block, max(nv, 1))
    nv_pad = -(-nv // nv_block) * nv_block
    if nv_pad != nv:
        xs = tuple(jnp.pad(x, ((0, 0), (0, nv_pad - nv))) for x in xs)
    n_x = sum(x.shape[0] for x in xs)
    if not rows_block:
        rows_block = _pick_rows_block(n_rows, kmax, n_x, nv_block)
    assert n_rows % rows_block == 0, (n_rows, rows_block)

    grid = (n_rows // rows_block, nv_pad // nv_block)
    in_specs = [
        pl.BlockSpec((rows_block, kmax), lambda i, v: (i, 0)),
        pl.BlockSpec((rows_block, kmax), lambda i, v: (i, 0)),
    ] + [
        pl.BlockSpec((x.shape[0], nv_block), lambda i, v: (0, v)) for x in xs
    ]
    out = pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows_block, nv_block), lambda i, v: (i, v)),
        out_shape=jax.ShapeDtypeStruct((n_rows, nv_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(cols, vals, *xs)
    return out[:, :nv] if nv_pad != nv else out
