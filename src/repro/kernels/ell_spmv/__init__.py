from repro.kernels.ell_spmv.kernel import ell_spmm_packed
from repro.kernels.ell_spmv.ref import ell_spmm_packed_ref, ell_spmv_ref

__all__ = ["ell_spmm_packed", "ell_spmm_packed_ref", "ell_spmv_ref"]
