"""User-facing jitted wrappers around the BSR SpMV Pallas kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmv.kernel import bsr_spmm_padded
from repro.sparse.bsr import BSR


def bsr_spmm(bsr: BSR, x, *, interpret: bool = True) -> jax.Array:
    """w = A @ x with x [n_cols, nv]; returns [n_rows, nv] (padded shape)."""
    cols, blocks, _ = bsr.padded_uniform()
    bm, bn = bsr.block_shape
    x = jnp.asarray(x, jnp.float32)
    n_bcols = bsr.shape[1] // bn
    pad_rows = bsr.shape[1] - x.shape[0]
    if pad_rows:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
    xb = x.reshape(n_bcols, bn, -1)
    out = bsr_spmm_padded(jnp.asarray(cols), jnp.asarray(blocks), xb,
                          interpret=interpret)
    return out.reshape(bsr.shape[0], -1)


def bsr_spmv(bsr: BSR, v, *, interpret: bool = True) -> jax.Array:
    """w = A @ v for a single vector; returns [n_rows] (padded shape)."""
    v = jnp.asarray(v, jnp.float32).reshape(-1, 1)
    return bsr_spmm(bsr, v, interpret=interpret).reshape(-1)
