"""Pure-jnp oracle for the BSR SpMV/SpMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def bsr_spmm_padded_ref(cols: jnp.ndarray, blocks: jnp.ndarray,
                        x: jnp.ndarray) -> jnp.ndarray:
    """Same contract as kernel.bsr_spmm_padded, via gather + einsum."""
    gathered = x[jnp.maximum(cols, 0)]                    # [nbr, kmax, bn, nv]
    valid = (cols >= 0)[..., None, None]
    prod = jnp.einsum("rkmn,rknv->rkmv", blocks,
                      jnp.where(valid, gathered, 0.0))
    return prod.sum(axis=1).astype(jnp.float32)


def bsr_spmv_ref(bsr, v):
    """Oracle on a sparse.BSR container + element vector (numpy/jnp)."""
    cols, blocks, _ = bsr.padded_uniform()
    bn = bsr.block_shape[1]
    x = jnp.asarray(v).reshape(-1, bn)[..., None]
    out = bsr_spmm_padded_ref(jnp.asarray(cols), jnp.asarray(blocks), x)
    return out.reshape(-1)
