"""Fused Pallas TPU kernel: all three NAPSpMV ``local_spmv`` calls in one.

Algorithm 3 multiplies three rank-local column blocks — on-process, on-node
and off-node — each against its own buffer (owned values, intra-node recv
buffer, inter-node recv buffer).  Running them as three scalar gathers (or
three separate kernels) reads the output tile three times and leaves the
MXU idle between calls.  Here the plan compiler concatenates the three
buffers into ONE padded x operand (``[v_loc | b_on_node | b_off_node]``,
each segment zero-padded up to the block grid) and rewrites the block
columns of all three matrices into that concatenated domain, so the whole
local compute is a single block-sparse matmul accumulating into one output
tile per block row.

Slot ordering is the overlap story of the paper's Algorithm 3 (and of
arXiv:1106.5908's explicit Isend/compute overlap): within each block row
the on-process slots come first, then on-node, then off-node.  The Pallas
pipeline streams (matrix block, x block) pairs in slot order with double
buffering, so the DMAs touching the last-arriving inter-node data are
issued last, behind the MXU work on locally-available blocks.

Multi-RHS (SpMM): x carries ``nv`` right-hand sides.  The nv axis is tiled
by ``nv_block`` as a second parallel grid axis, bounding VMEM per step at

    (bm x bn  +  bn x nv_block  +  bm x nv_block) x 4 bytes

e.g. 192 KiB at (128, 128, 128) — double buffered < 0.5 MiB of ~16 MiB
VMEM; at nv = 1024 the nv tiling keeps the budget flat where an untiled x
block would claim 0.5 MiB per operand on its own.

Padding slots (block col == -1) carry all-zero matrix blocks, so they are
mathematically inert; the index_map clamps them to 0 to stay in bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _fused_kernel(cols_ref, blk_ref, x_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(blk_ref[0, 0], x_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("nv_block", "interpret"))
def fused_bsr_spmm(cols: jax.Array, blocks: jax.Array, x: jax.Array,
                   *, nv_block: int = 128, interpret: bool = True) -> jax.Array:
    """w = A @ x for the fused padded-uniform BSR layout, nv-tiled.

    cols:   [n_brows, ktot] int32 block-column ids into the concatenated
            x domain (-1 = padding slot)
    blocks: [n_brows, ktot, bm, bn] (padding slots zero-filled)
    x:      [n_bcols, bn, nv] — concat(v_loc, b_on_node, b_off_node) blocks
    returns [n_brows, bm, nv] float32

    Grid: (n_brows, nv_tiles, ktot) — block rows and nv tiles are parallel,
    the slot axis is the sequential accumulation axis.  nv is padded up to a
    multiple of ``nv_block`` and sliced back.
    """
    n_brows, ktot, bm, bn = blocks.shape
    nv = x.shape[-1]
    nv_block = min(nv_block, max(nv, 1))
    nv_pad = -(-nv // nv_block) * nv_block
    if nv_pad != nv:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, nv_pad - nv)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, nv_pad // nv_block, ktot),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda i, v, k, cols: (i, k, 0, 0)),
            # the sparse gather: x block chosen by the prefetched col id
            pl.BlockSpec((1, bn, nv_block),
                         lambda i, v, k, cols: (jnp.maximum(cols[i, k], 0), 0, v)),
        ],
        out_specs=pl.BlockSpec((1, bm, nv_block), lambda i, v, k, cols: (i, 0, v)),
    )
    out = pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, bm, nv_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, blocks, x)
    return out[..., :nv] if nv_pad != nv else out


def fused_bsr_spmm_ref(cols, blocks, x) -> jnp.ndarray:
    """Pure-jnp oracle with the same contract as :func:`fused_bsr_spmm`."""
    gathered = x[jnp.maximum(cols, 0)]                    # [nbr, ktot, bn, nv]
    valid = (cols >= 0)[..., None, None]
    prod = jnp.einsum("rkmn,rknv->rkmv", blocks,
                      jnp.where(valid, gathered, 0.0))
    return prod.sum(axis=1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Zero-copy packed-x variant
# ---------------------------------------------------------------------------
#
# Same math as fused_bsr_spmm, but the x operand arrives as SEPARATE
# bn-aligned segments (v_loc, b_on_node, b_off_node) instead of one
# HBM-materialised concat.  Each segment gets its own ref whose index_map
# routes the prefetched block-column id into that segment's local block
# index (clamped to 0 when the slot belongs to another segment); the
# kernel then selects the one block that is in range.  Because an
# out-of-range ref's index_map pins it to block 0, the Pallas pipeline
# re-fetches it only on segment transitions — slots are sorted
# on-process -> on-node -> off-node, so each x ref's DMA stream stays
# monotone and the extra traffic is at most one block per segment switch.


def _make_packed_kernel(bounds):
    def kernel(cols_ref, blk_ref, *rest):
        *x_refs, o_ref = rest
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        c = cols_ref[pl.program_id(0), k]
        x = x_refs[-1][0]
        for b, x_ref in zip(reversed(bounds[:-1]), reversed(x_refs[:-1])):
            x = jnp.where(c < b, x_ref[0], x)
        o_ref[...] += jnp.dot(blk_ref[0, 0], x,
                              preferred_element_type=jnp.float32)

    return kernel


def _segment_spec(lo, hi, bn, nv_block):
    # route block col c into this segment's local index; pin to 0 otherwise
    def index_map(i, v, k, cols):
        c = cols[i, k]
        return (jnp.where((c >= lo) & (c < hi), c - lo, 0), 0, v)

    return pl.BlockSpec((1, bn, nv_block), index_map)


@functools.partial(jax.jit, static_argnames=("nv_block", "interpret"))
def fused_bsr_spmm_packed(cols: jax.Array, blocks: jax.Array, xs, *,
                          nv_block: int = 128,
                          interpret: bool = True) -> jax.Array:
    """w = A @ concat(xs) without materialising the concat in HBM.

    cols:   [n_brows, ktot] int32 block-column ids into the packed domain
            (-1 = padding slot); segment s covers block columns
            [sum(len(xs[:s])), sum(len(xs[:s+1]))) in block units
    blocks: [n_brows, ktot, bm, bn] (padding slots zero-filled)
    xs:     tuple of [n_bcols_s, bn, nv] segments (1..3 of them)
    returns [n_brows, bm, nv] float32 — bit-for-bit equal to
    ``fused_bsr_spmm(cols, blocks, jnp.concatenate(xs))``.
    """
    xs = tuple(jnp.asarray(x, jnp.float32) for x in xs)
    n_brows, ktot, bm, bn = blocks.shape
    nv = xs[0].shape[-1]
    nv_block = min(nv_block, max(nv, 1))
    nv_pad = -(-nv // nv_block) * nv_block
    if nv_pad != nv:
        xs = tuple(jnp.pad(x, ((0, 0), (0, 0), (0, nv_pad - nv))) for x in xs)
    bounds = []
    acc = 0
    for x in xs:
        acc += x.shape[0]
        bounds.append(acc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, nv_pad // nv_block, ktot),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda i, v, k, cols: (i, k, 0, 0)),
        ] + [
            _segment_spec(lo, hi, bn, nv_block)
            for lo, hi in zip([0] + bounds[:-1], bounds)
        ],
        out_specs=pl.BlockSpec((1, bm, nv_block), lambda i, v, k, cols: (i, 0, v)),
    )
    out = pl.pallas_call(
        _make_packed_kernel(tuple(bounds)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, bm, nv_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, blocks, *xs)
    return out[..., :nv] if nv_pad != nv else out
