from repro.kernels.bsr_spmv.ops import bsr_spmv, bsr_spmm
from repro.kernels.bsr_spmv.ref import bsr_spmv_ref

__all__ = ["bsr_spmv", "bsr_spmm", "bsr_spmv_ref"]
