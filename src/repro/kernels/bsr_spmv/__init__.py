from repro.kernels.bsr_spmv.fused import (fused_bsr_spmm, fused_bsr_spmm_packed,
                                          fused_bsr_spmm_ref)
from repro.kernels.bsr_spmv.ops import bsr_spmv, bsr_spmm
from repro.kernels.bsr_spmv.ref import bsr_spmv_ref

__all__ = ["bsr_spmv", "bsr_spmm", "bsr_spmv_ref",
           "fused_bsr_spmm", "fused_bsr_spmm_packed", "fused_bsr_spmm_ref"]
