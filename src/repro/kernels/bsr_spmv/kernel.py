"""Pallas TPU kernel: block-sparse-row SpMV / SpMM (the NAPSpMV local_spmv).

TPU adaptation of the paper's MKL/Eigen CSR ``local_spmv`` (DESIGN.md §2):
scalar CSR row kernels cannot feed the 128x128 MXU, so the local matrix is
stored as BSR with MXU-aligned dense blocks (``sparse/bsr.py``) and each
(block-row i, slot k) grid step issues one ``(bm, bn) @ (bn, nv)`` MXU
matmul against the x-block selected by the **scalar-prefetched** block-column
index — the sparse gather happens in the BlockSpec index_map, so the block
DMA (HBM -> VMEM) is overlapped with compute by the Pallas pipeline (the
double buffering the paper gets from posting MPI_Isend early).

Layout/VMEM budget per grid step (f32):
  matrix block  (bm, bn)        = 64 KiB at 128x128
  x block       (bn, nv)        = 64 KiB at nv = 128
  out block     (bm, nv)        = 64 KiB
With double buffering this is < 0.5 MiB of ~16 MiB VMEM, leaving headroom
for larger nv or multi-row blocks.

Padding slots (block col == -1) carry all-zero matrix blocks, so they are
mathematically inert; the index_map clamps them to 0 to stay in bounds.

The distributed executor (``core/spmv_jax.py``) does NOT call this kernel
three times for Algorithm 3's three ``local_spmv`` blocks — it uses the
**fused** variant in :mod:`repro.kernels.bsr_spmv.fused`, which multiplies
the on-process / on-node / off-node blocks against one concatenated x
operand in a single ``pallas_call`` (one output-tile accumulation, slots
ordered so locally-available blocks are streamed first).  The fused kernel
also tiles the nv (multi-RHS) axis: at nv = 128 the per-step VMEM budget
matches the figure above; at larger nv the budget stays flat because nv is
a parallel grid axis, not a larger block.  See fused.py for the breakdown
and what remains for a real multi-host mesh (ROADMAP "Open items").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(cols_ref, blk_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(blk_ref[0, 0], x_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmm_padded(cols: jax.Array, blocks: jax.Array, x: jax.Array,
                    *, interpret: bool = True) -> jax.Array:
    """w = A @ x for the padded-uniform BSR layout.

    cols:   [n_brows, kmax] int32 block-column ids (-1 = padding)
    blocks: [n_brows, kmax, bm, bn] (padding slots zero-filled)
    x:      [n_bcols, bn, nv]
    returns [n_brows, bm, nv] float32
    """
    n_brows, kmax, bm, bn = blocks.shape
    nv = x.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda i, k, cols: (i, k, 0, 0)),
            # the sparse gather: x block chosen by the prefetched col id
            pl.BlockSpec((1, bn, nv),
                         lambda i, k, cols: (jnp.maximum(cols[i, k], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, nv), lambda i, k, cols: (i, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, bm, nv), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, blocks, x)
